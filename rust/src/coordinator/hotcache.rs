//! `hotcache` — the hot-key read tier: a sharded, fixed-capacity cache
//! in front of the route + storage GET path, with epoch-validated
//! entries and single-flight miss coalescing (DESIGN.md §14).
//!
//! Under the Zipf/hot-set workloads loadgen generates, a handful of keys
//! take most of the read traffic, and every one of those GETs pays the
//! full route + 16-way storage shard lock round trip. This tier answers
//! repeat reads from a read-locked map probe instead. Three rules keep
//! it correct without TTLs or cross-thread bookkeeping:
//!
//! * **Epoch validity.** Every entry carries the router epoch it was
//!   filled at; a hit is served only if that epoch equals the caller's
//!   current [`crate::coordinator::router::Router::snapshot`] epoch.
//!   Epochs are monotone and never reused, so a KILL/ADD/SETW/migration
//!   publish invalidates every cached entry *for free* — stale-epoch
//!   entries simply never hit again and age out under CLOCK.
//! * **Write-through invalidation.** A PUT removes the key's entry and
//!   bumps the owning shard's generation counter inside the same write
//!   lock, so an in-flight fill that read storage *before* the PUT can
//!   never install the pre-PUT value afterwards (the fill re-checks the
//!   generation under the write lock and aborts on mismatch).
//! * **Single flight.** N concurrent misses on one key collapse into one
//!   storage read: the first becomes the leader, the rest park on a
//!   per-key in-flight slot and reuse the leader's result. A follower
//!   whose join-time generation differs from the flight's performs its
//!   own read instead — a GET that starts after a PUT's ack must never
//!   consume a pre-PUT value published by an older leader.
//!
//! Values never change during migration (records relocate verbatim), so
//! a `Found` value cached from any read path — including the migration
//! failover path — is safe to serve for as long as its epoch matches.
//! `Absent` results are never cached: a negative entry could mask a
//! replica or migration install that no epoch bump announces.

use crate::coordinator::membership::NodeId;
use crate::metrics::{Counter, MetricSpec, ShardedCounter};
use crate::sync::{lock_recover, read_recover, write_recover};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError, RwLock};

/// Sizing knobs for a [`HotCache`].
#[derive(Debug, Clone, Copy)]
pub struct HotCacheConfig {
    /// Target total entry count across all shards. Rounded up so each
    /// shard holds a power-of-two slot array (CLOCK hand arithmetic is
    /// a mask).
    pub capacity: usize,
    /// Shard count (power of two). Hits take a per-shard *read* lock,
    /// so concurrent readers of one hot key scale across threads; more
    /// shards only reduce fill/invalidate write contention.
    pub shards: usize,
}

impl Default for HotCacheConfig {
    fn default() -> Self {
        Self { capacity: 4096, shards: 16 }
    }
}

/// The result of one storage read, as the cache sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Loaded {
    /// The key exists on `NodeId` with this value (cacheable).
    Found(NodeId, Arc<str>),
    /// The key does not exist; `NodeId` is the primary that was asked
    /// (never cached — see the module docs on negative entries).
    Absent(NodeId),
}

/// One cached entry. `referenced` is the CLOCK second-chance bit, set
/// under the shard *read* lock on every hit (an `AtomicBool` store, so
/// hits never upgrade to the write lock).
#[derive(Debug)]
struct Slot {
    key: u64,
    epoch: u64,
    node: NodeId,
    value: Arc<str>,
    referenced: AtomicBool,
}

/// The lock-guarded face of one shard: the slot array + index, the
/// CLOCK hand, and the generation counter that serializes fills against
/// invalidations (both hold the write lock, so the pair
/// {check gen, insert} / {bump gen, remove} is atomic).
#[derive(Debug)]
struct ShardState {
    slots: Vec<Option<Slot>>,
    index: HashMap<u64, usize>,
    hand: usize,
    live: usize,
    gen: u64,
}

/// A parked miss: the leader publishes its result here and wakes the
/// followers. `gen0` is the shard generation the leader observed before
/// reading storage — followers that join at a later generation must not
/// consume the (possibly pre-PUT) result.
#[derive(Debug)]
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
    gen0: u64,
}

#[derive(Debug)]
enum FlightState {
    Pending,
    Done(Loaded),
    /// The leader panicked or unwound without publishing; followers
    /// fall back to their own storage read.
    Failed,
}

#[derive(Debug)]
struct Shard {
    state: RwLock<ShardState>,
    /// In-flight loads by key. Tiny map (one entry per concurrently
    /// missing key in this shard), guarded separately from `state` so
    /// parked followers never hold the cache lock.
    flights: Mutex<HashMap<u64, Arc<Flight>>>,
}

impl Shard {
    fn new(slots_per_shard: usize) -> Self {
        Self {
            state: RwLock::new(ShardState {
                slots: (0..slots_per_shard).map(|_| None).collect(),
                index: HashMap::new(),
                hand: 0,
                live: 0,
                gen: 0,
            }),
            flights: Mutex::new(HashMap::new()),
        }
    }
}

/// Publishes the leader's flight outcome exactly once — on the success
/// path via [`FlightGuard::publish`], or as `Failed` from `Drop` if the
/// loader panics, so followers are never stranded on the condvar.
struct FlightGuard<'a> {
    shard: &'a Shard,
    key: u64,
    flight: Arc<Flight>,
    done: bool,
}

impl FlightGuard<'_> {
    fn publish(&mut self, result: Loaded) {
        self.resolve(FlightState::Done(result));
    }

    fn resolve(&mut self, state: FlightState) {
        *lock_recover(&self.flight.state) = state;
        self.flight.cv.notify_all();
        // Remove *after* publishing (and after the caller's cache fill):
        // a thread that misses the flight map sees the filled cache on
        // its leader re-probe instead of issuing a second storage read.
        lock_recover(&self.shard.flights).remove(&self.key);
        self.done = true;
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.resolve(FlightState::Failed);
        }
    }
}

/// The hot-key read tier. See the module docs for the validity rules.
#[derive(Debug)]
pub struct HotCache {
    shards: Vec<Shard>,
    /// Cache hits served (epoch matched). Striped: this ticks on the
    /// read-locked hot path.
    hits: ShardedCounter,
    /// GETs that went to storage (cold key, stale epoch, coalesced wait,
    /// or generation-bumped fallback). `hits + misses` equals the GETs
    /// that entered the cache path.
    misses: ShardedCounter,
    /// Misses that reused a leader's storage read instead of their own.
    coalesced: Counter,
    /// Entries evicted by the CLOCK hand to make room.
    evictions: Counter,
    /// Entries removed by write-through invalidation (PUT on a cached
    /// key).
    invalidations: Counter,
}

impl HotCache {
    /// Build a cache with `cfg.shards` shards of
    /// `next_power_of_two(capacity / shards)` slots each.
    pub fn new(cfg: HotCacheConfig) -> Self {
        assert!(cfg.shards.is_power_of_two(), "shard count must be a power of two");
        let per_shard = (cfg.capacity / cfg.shards).max(4).next_power_of_two();
        Self {
            shards: (0..cfg.shards).map(|_| Shard::new(per_shard)).collect(),
            hits: ShardedCounter::new(),
            misses: ShardedCounter::new(),
            coalesced: Counter::new(),
            evictions: Counter::new(),
            invalidations: Counter::new(),
        }
    }

    fn shard(&self, key: u64) -> &Shard {
        let i = crate::hashing::mix::splitmix64_mix(key) as usize & (self.shards.len() - 1);
        &self.shards[i]
    }

    /// Look the key up under the shard read lock. A hit requires the
    /// entry's fill epoch to equal `epoch` (the caller's current router
    /// epoch); anything else is a miss and the caller proceeds to
    /// [`HotCache::load_coalesced`].
    pub fn probe(&self, key: u64, epoch: u64) -> Option<(NodeId, Arc<str>)> {
        let shard = self.shard(key);
        let st = read_recover(&shard.state);
        if let Some(&i) = st.index.get(&key) {
            if let Some(slot) = &st.slots[i] {
                if slot.epoch == epoch {
                    slot.referenced.store(true, Ordering::Relaxed);
                    self.hits.inc();
                    return Some((slot.node, slot.value.clone()));
                }
            }
        }
        None
    }

    /// Resolve a miss with single-flight coalescing: the first caller
    /// for `key` runs `loader` (one storage read) and fills the cache;
    /// concurrent callers park and reuse its result. `epoch` tags the
    /// fill — read it from the same router snapshot as the failed probe
    /// (an epoch that has since moved on just yields an entry that never
    /// hits, which is safe).
    pub fn load_coalesced<F: FnOnce() -> Loaded>(
        &self,
        key: u64,
        epoch: u64,
        loader: F,
    ) -> Loaded {
        let shard = self.shard(key);
        // Generation first, flight second: a PUT landing in between only
        // makes gen0 stale, which disables the fill — never stales it.
        let gen_now = read_recover(&shard.state).gen;
        let (flight, is_leader) = {
            let mut flights = lock_recover(&shard.flights);
            match flights.get(&key) {
                Some(f) => (f.clone(), false),
                None => {
                    let f = Arc::new(Flight {
                        state: Mutex::new(FlightState::Pending),
                        cv: Condvar::new(),
                        gen0: gen_now,
                    });
                    flights.insert(key, f.clone());
                    (f, true)
                }
            }
        };

        if is_leader {
            let mut guard = FlightGuard { shard, key, flight, done: false };
            // A prior leader may have completed between this thread's
            // probe miss and the flight insertion above; its fill is
            // visible before its flight removal, so a re-probe (not a
            // second storage read) closes that race.
            if let Some((node, value)) = self.probe(key, epoch) {
                let loaded = Loaded::Found(node, value);
                guard.publish(loaded.clone());
                return loaded;
            }
            self.misses.inc();
            let loaded = loader();
            if let Loaded::Found(node, ref value) = loaded {
                self.fill(shard, key, epoch, node, value.clone(), gen_now);
            }
            guard.publish(loaded.clone());
            return loaded;
        }

        // Follower. If the shard generation moved past the leader's, a
        // PUT was acknowledged after the leader started — this GET began
        // after that ack, so the leader's value would be a stale read.
        if gen_now != flight.gen0 {
            self.misses.inc();
            return loader();
        }
        let mut st = lock_recover(&flight.state);
        loop {
            match &*st {
                FlightState::Pending => {
                    st = flight.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                }
                FlightState::Done(loaded) => {
                    let loaded = loaded.clone();
                    drop(st);
                    self.misses.inc();
                    self.coalesced.inc();
                    return loaded;
                }
                FlightState::Failed => {
                    drop(st);
                    self.misses.inc();
                    return loader();
                }
            }
        }
    }

    /// Install a loaded value, unless the shard generation moved since
    /// the leader observed `gen0` (a PUT invalidated this key — or a
    /// neighbor in the shard — mid-load; dropping the fill is the safe
    /// side).
    fn fill(&self, shard: &Shard, key: u64, epoch: u64, node: NodeId, value: Arc<str>, gen0: u64) {
        let mut st = write_recover(&shard.state);
        if st.gen != gen0 {
            return;
        }
        // Cold insertion: the second-chance bit starts clear, so a key
        // earns its lap of protection only on a repeat hit — one-shot
        // scans cycle through the probation slot instead of flushing the
        // established hot set.
        let slot = Slot { key, epoch, node, value, referenced: AtomicBool::new(false) };
        if let Some(&i) = st.index.get(&key) {
            // Refresh in place (e.g. a stale-epoch entry for this key).
            st.slots[i] = Some(slot);
            return;
        }
        // CLOCK sweep: free slot, or the first entry whose second-chance
        // bit is already clear. Bounded: one full lap clears every bit.
        let mask = st.slots.len() - 1;
        let mut i = st.hand;
        let victim = loop {
            let evict = match &st.slots[i] {
                None => break i,
                Some(s) => {
                    if s.referenced.swap(false, Ordering::Relaxed) {
                        None
                    } else {
                        Some(s.key)
                    }
                }
            };
            if let Some(k) = evict {
                st.index.remove(&k);
                st.live -= 1;
                self.evictions.inc();
                break i;
            }
            i = (i + 1) & mask;
        };
        st.slots[victim] = Some(slot);
        st.index.insert(key, victim);
        st.live += 1;
        st.hand = (victim + 1) & mask;
    }

    /// Write-through invalidation: remove the key's entry and bump the
    /// shard generation in one write-locked step, so no in-flight fill
    /// that read storage before the write can land afterwards. Call
    /// after the storage write, before acknowledging it.
    pub fn invalidate(&self, key: u64) {
        let shard = self.shard(key);
        let mut st = write_recover(&shard.state);
        st.gen = st.gen.wrapping_add(1);
        if let Some(i) = st.index.remove(&key) {
            st.slots[i] = None;
            st.live -= 1;
            self.invalidations.inc();
        }
    }

    /// Live entry count across all shards (point-in-time).
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| read_recover(&s.state).live).sum()
    }

    /// `(hits, misses, coalesced)` since construction. `hits + misses`
    /// equals the GETs that entered the cache path.
    pub fn op_counts(&self) -> (u64, u64, u64) {
        (self.hits.get(), self.misses.get(), self.coalesced.get())
    }

    /// Point-in-time enumeration of every cache metric — the single
    /// source behind [`HotCache::summary`] and the registry exposition
    /// (see [`crate::metrics::RouterMetrics::metric_specs`] for the
    /// contract).
    pub fn metric_specs(&self) -> Vec<MetricSpec> {
        vec![
            MetricSpec::counter(
                "hits",
                "Hot-key cache hits (entry epoch matched the router epoch).",
                self.hits.get(),
            ),
            MetricSpec::counter(
                "misses",
                "GETs that went to storage (cold, stale epoch, or coalesced).",
                self.misses.get(),
            ),
            MetricSpec::counter(
                "coalesced",
                "Misses that reused a concurrent leader's storage read.",
                self.coalesced.get(),
            ),
            MetricSpec::counter(
                "evictions",
                "Entries evicted by the CLOCK hand.",
                self.evictions.get(),
            ),
            MetricSpec::counter(
                "invalidations",
                "Entries removed by write-through invalidation.",
                self.invalidations.get(),
            ),
            MetricSpec::gauge(
                "entries",
                "Live cached entries across all shards.",
                self.entries() as u64,
            ),
        ]
    }

    /// One-line summary (the `CACHESTAT` protocol payload), generated
    /// from [`HotCache::metric_specs`].
    pub fn summary(&self) -> String {
        MetricSpec::join(&self.metric_specs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;
    use std::time::Duration;

    fn one_shard(capacity: usize) -> HotCache {
        HotCache::new(HotCacheConfig { capacity, shards: 1 })
    }

    fn found(node: u64, v: &str) -> Loaded {
        Loaded::Found(NodeId(node), Arc::from(v))
    }

    #[test]
    fn fill_then_hit_at_the_same_epoch() {
        let c = one_shard(64);
        assert!(c.probe(7, 0).is_none());
        let l = c.load_coalesced(7, 0, || found(3, "v7"));
        assert_eq!(l, found(3, "v7"));
        let (node, value) = c.probe(7, 0).expect("filled entry must hit");
        assert_eq!(node, NodeId(3));
        assert_eq!(&*value, "v7");
        let (hits, misses, coalesced) = c.op_counts();
        assert_eq!((hits, misses, coalesced), (1, 1, 0));
        assert_eq!(c.entries(), 1);
    }

    #[test]
    fn an_epoch_bump_invalidates_every_entry() {
        let c = one_shard(64);
        for k in 0..10u64 {
            c.load_coalesced(k, 4, || found(k, "x"));
        }
        for k in 0..10u64 {
            assert!(c.probe(k, 4).is_some(), "k={k} valid at its fill epoch");
            assert!(c.probe(k, 5).is_none(), "k={k} must not hit at a newer epoch");
        }
        // Refill at the new epoch reuses the slot in place.
        c.load_coalesced(3, 5, || found(9, "y"));
        let (node, _v) = c.probe(3, 5).unwrap();
        assert_eq!(node, NodeId(9));
        assert_eq!(c.entries(), 10, "refresh must not grow the cache");
    }

    #[test]
    fn absent_results_are_never_cached() {
        let c = one_shard(64);
        let l = c.load_coalesced(11, 0, || Loaded::Absent(NodeId(2)));
        assert_eq!(l, Loaded::Absent(NodeId(2)));
        assert!(c.probe(11, 0).is_none(), "negative entries are not cached");
        assert_eq!(c.entries(), 0);
    }

    #[test]
    fn invalidate_removes_the_entry_and_aborts_in_flight_fills() {
        let c = one_shard(64);
        c.load_coalesced(1, 0, || found(5, "old"));
        assert!(c.probe(1, 0).is_some());
        c.invalidate(1);
        assert!(c.probe(1, 0).is_none(), "write-through must remove the entry");
        // A loader that races a PUT: the invalidate lands between the
        // generation read and the fill, so the fill must be dropped.
        let l = c.load_coalesced(1, 0, || {
            c.invalidate(1);
            found(5, "pre-put")
        });
        assert_eq!(l, found(5, "pre-put"), "the caller still gets its read");
        assert!(c.probe(1, 0).is_none(), "a gen-bumped fill must not install");
    }

    #[test]
    fn clock_eviction_caps_the_shard_and_spares_referenced_entries() {
        let c = one_shard(8); // one shard, 8 slots
        for k in 0..8u64 {
            c.load_coalesced(k, 0, || found(k, "v"));
        }
        assert_eq!(c.entries(), 8);
        // Touch key 0 so its second-chance bit is set, then overflow.
        assert!(c.probe(0, 0).is_some());
        for k in 100..104u64 {
            c.load_coalesced(k, 0, || found(k, "v"));
        }
        assert_eq!(c.entries(), 8, "capacity is a hard cap");
        assert!(c.probe(0, 0).is_some(), "referenced entry survives one sweep");
        let evicted = (1..8u64).filter(|&k| c.probe(k, 0).is_none()).count();
        assert_eq!(evicted, 4, "each overflow fill evicts exactly one entry");
    }

    #[test]
    fn concurrent_misses_on_one_key_perform_one_load() {
        let c = Arc::new(one_shard(64));
        let loads = Arc::new(AtomicUsize::new(0));
        let start = Arc::new(Barrier::new(8));
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let (c, loads, start) = (c.clone(), loads.clone(), start.clone());
                std::thread::spawn(move || {
                    start.wait();
                    c.load_coalesced(42, 0, || {
                        loads.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(20));
                        found(1, "v42")
                    })
                })
            })
            .collect();
        for h in hs {
            assert_eq!(h.join().unwrap(), found(1, "v42"));
        }
        assert_eq!(loads.load(Ordering::SeqCst), 1, "exactly one storage read");
        let (hits, misses, _) = c.op_counts();
        assert_eq!(hits + misses, 8, "every caller is either a hit or a miss");
    }

    #[test]
    fn followers_at_a_newer_generation_do_their_own_read() {
        let c = Arc::new(one_shard(64));
        let release = Arc::new(Barrier::new(2));
        let leader = {
            let (c, release) = (c.clone(), release.clone());
            std::thread::spawn(move || {
                c.load_coalesced(7, 0, || {
                    release.wait(); // flight is registered; let the test proceed
                    std::thread::sleep(Duration::from_millis(30));
                    found(1, "pre-put")
                })
            })
        };
        release.wait();
        // A PUT acks while the leader is mid-read…
        c.invalidate(7);
        // …so a GET issued after that ack must not adopt the leader's
        // (pre-PUT) result: the generation check forces a fresh read.
        let own = Arc::new(AtomicUsize::new(0));
        let l = {
            let own = own.clone();
            c.load_coalesced(7, 0, || {
                own.fetch_add(1, Ordering::SeqCst);
                found(1, "post-put")
            })
        };
        assert_eq!(l, found(1, "post-put"));
        assert_eq!(own.load(Ordering::SeqCst), 1, "follower must re-read");
        assert_eq!(leader.join().unwrap(), found(1, "pre-put"));
        // The leader's fill aborts on the generation mismatch; at most
        // the fresh read may be installed, never the pre-PUT value.
        if let Some((_n, v)) = c.probe(7, 0) {
            assert_eq!(&*v, "post-put", "the pre-PUT value must never be cached");
        }
        let (_h, _m, coalesced) = c.op_counts();
        assert_eq!(coalesced, 0, "a gen-mismatched follower is not a coalesced read");
    }

    #[test]
    fn a_panicking_leader_does_not_strand_followers() {
        let c = Arc::new(one_shard(64));
        let release = Arc::new(Barrier::new(2));
        let leader = {
            let (c, release) = (c.clone(), release.clone());
            std::thread::spawn(move || {
                c.load_coalesced(9, 0, || -> Loaded {
                    release.wait();
                    std::thread::sleep(Duration::from_millis(20));
                    panic!("storage exploded mid-read");
                })
            })
        };
        release.wait();
        // Joins the pending flight, then recovers via its own read once
        // the leader's guard publishes Failed.
        let l = c.load_coalesced(9, 0, || found(2, "recovered"));
        assert_eq!(l, found(2, "recovered"));
        assert!(leader.join().is_err(), "the leader's panic propagates to it alone");
        assert!(
            lock_recover(&c.shard(9).flights).is_empty(),
            "a failed flight must not leak"
        );
    }

    #[test]
    fn metric_specs_cover_the_summary_and_stay_unique() {
        let c = one_shard(64);
        c.load_coalesced(1, 0, || found(1, "v"));
        c.probe(1, 0);
        c.invalidate(1);
        let s = c.summary();
        for spec in c.metric_specs() {
            assert!(
                s.contains(&format!("{}={}", spec.name, spec.value)),
                "summary {s:?} omits {}",
                spec.name
            );
        }
        let names: Vec<&str> = c.metric_specs().iter().map(|sp| sp.name).collect();
        let dedup: std::collections::HashSet<&str> = names.iter().copied().collect();
        assert_eq!(dedup.len(), names.len());
        assert!(s.contains("hits=1"), "{s}");
        assert!(s.contains("invalidations=1"), "{s}");
        assert!(s.contains("entries=0"), "{s}");
    }

    #[test]
    fn shard_selection_spreads_keys() {
        let c = HotCache::new(HotCacheConfig { capacity: 1024, shards: 16 });
        for k in 0..512u64 {
            c.load_coalesced(k, 0, || found(k, "v"));
        }
        assert_eq!(c.entries(), 512);
        let populated = c.shards.iter().filter(|s| read_recover(&s.state).live > 0).count();
        assert!(populated >= 12, "512 keys must land on most of 16 shards: {populated}");
    }
}
