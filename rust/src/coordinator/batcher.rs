//! Dynamic batcher: collect individual lookups into device-sized batches.
//!
//! The paper's lookup cost is per key; the engine's cost is per *dispatch*.
//! The batcher closes the gap: requests queue until `batch_size` are
//! pending or `timeout` elapses (whichever first), then one flush resolves
//! the whole batch (vLLM-style continuous batching, specialized to
//! request/response lookups).

use super::router::Router;
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One queued lookup: key + where to deliver the bucket.
struct Pending {
    key: u64,
    reply: Sender<u32>,
}

/// Handle for submitting lookups to the batcher.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<Pending>,
}

impl BatcherHandle {
    /// Submit a key; blocks until the batch containing it is resolved.
    pub fn lookup(&self, key: u64) -> Option<u32> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx.send(Pending { key, reply: reply_tx }).ok()?;
        reply_rx.recv().ok()
    }

    /// Submit a key and return the reply receiver (pipelined submission:
    /// callers can submit many keys before collecting).
    pub fn lookup_async(&self, key: u64) -> Option<Receiver<u32>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx.send(Pending { key, reply: reply_tx }).ok()?;
        Some(reply_rx)
    }
}

/// The batcher worker; drop the handle(s) and join to stop.
pub struct Batcher {
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the batching loop over `router`.
    pub fn spawn(
        router: Arc<Router>,
        batch_size: usize,
        timeout: Duration,
    ) -> (Self, BatcherHandle) {
        let (tx, rx) = std::sync::mpsc::sync_channel::<Pending>(batch_size * 8);
        let worker = std::thread::Builder::new()
            .name("memento-batcher".into())
            .spawn(move || Self::run(router, rx, batch_size, timeout))
            .expect("spawn batcher");
        (Self { worker: Some(worker) }, BatcherHandle { tx })
    }

    fn run(
        router: Arc<Router>,
        rx: Receiver<Pending>,
        batch_size: usize,
        timeout: Duration,
    ) {
        let mut queue: Vec<Pending> = Vec::with_capacity(batch_size);
        loop {
            // Block for the first request of a batch.
            match rx.recv() {
                Ok(p) => queue.push(p),
                Err(_) => return, // all handles dropped
            }
            let deadline = Instant::now() + timeout;
            // Fill until full or deadline.
            while queue.len() < batch_size {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => queue.push(p),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            // Flush.
            let keys: Vec<u64> = queue.iter().map(|p| p.key).collect();
            let buckets = router.route_batch(&keys);
            for (p, b) in queue.drain(..).zip(buckets) {
                let _ = p.reply.send(b); // receiver may have given up: fine
            }
        }
    }

    /// Wait for the worker to exit (after all handles are dropped).
    pub fn join(mut self) {
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_router() -> Arc<Router> {
        Router::new("memento", 16, 160, None).unwrap()
    }

    #[test]
    fn single_lookup_resolves() {
        let router = test_router();
        let (batcher, handle) =
            Batcher::spawn(router.clone(), 64, Duration::from_micros(200));
        let key = crate::hashing::mix::splitmix64_mix(42);
        let b = handle.lookup(key).unwrap();
        assert_eq!(b, router.route(key).0);
        drop(handle);
        batcher.join();
    }

    #[test]
    fn batched_results_match_scalar() {
        let router = test_router();
        let (batcher, handle) =
            Batcher::spawn(router.clone(), 32, Duration::from_micros(500));
        // Pipelined submission from one thread.
        let keys: Vec<u64> =
            (0..200u64).map(crate::hashing::mix::splitmix64_mix).collect();
        let rxs: Vec<_> = keys.iter().map(|&k| handle.lookup_async(k).unwrap()).collect();
        for (k, rx) in keys.iter().zip(rxs) {
            assert_eq!(rx.recv().unwrap(), router.route(*k).0);
        }
        drop(handle);
        batcher.join();
    }

    #[test]
    fn concurrent_submitters() {
        let router = test_router();
        let (batcher, handle) =
            Batcher::spawn(router.clone(), 64, Duration::from_micros(300));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = handle.clone();
                let r = router.clone();
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        let key = crate::hashing::mix::splitmix64_mix(t * 1000 + i);
                        assert_eq!(h.lookup(key).unwrap(), r.route(key).0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        drop(handle);
        batcher.join();
    }

    #[test]
    fn timeout_flushes_partial_batches() {
        let router = test_router();
        // Huge batch size: only the timeout can flush.
        let (batcher, handle) =
            Batcher::spawn(router.clone(), 1_000_000, Duration::from_millis(5));
        let t = Instant::now();
        let b = handle.lookup(7).unwrap();
        assert!(t.elapsed() < Duration::from_secs(1));
        assert_eq!(b, router.route(7).0);
        drop(handle);
        batcher.join();
    }
}
