//! Rebalance auditor: verifies at runtime that resizes obey the paper's
//! minimal-disruption (Prop. VI.3) and monotonicity (Prop. VI.5) bounds.
//!
//! The router calls [`Rebalancer::observe_epoch`] with a tracer key set on
//! every membership change; violations (collateral key movement) are
//! counted and surfaced in `STATS` — in a correct deployment of a strictly
//! minimal-disruptive algorithm they are always zero.

use super::router::Router;
use crate::simulator::audit;
use crate::sync::lock_recover;
use std::sync::Mutex;

/// Running audit over membership epochs.
pub struct Rebalancer {
    tracer_keys: Vec<u64>,
    state: Mutex<State>,
}

struct State {
    last_assignment: Vec<u32>,
    /// Total keys relocated across all observed epochs.
    pub relocated: u64,
    /// Total collateral movements (bound violations).
    pub violations: u64,
    epochs_observed: u64,
    /// Relocated fraction of the tracer set over the last observed epoch.
    last_relocated_frac: f64,
}

/// Summary of the audit so far.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceSummary {
    /// Membership epochs audited so far.
    pub epochs_observed: u64,
    /// Total tracer keys relocated across all observed epochs.
    pub relocated: u64,
    /// Total collateral movements (bound violations).
    pub violations: u64,
    /// Relocated fraction of the tracer set over the last epoch.
    pub last_relocated_frac: f64,
}

impl Rebalancer {
    /// Create with `tracers` deterministic probe keys.
    pub fn new(router: &Router, tracers: usize, seed: u64) -> Self {
        let tracer_keys: Vec<u64> = (0..tracers as u64)
            .map(|i| crate::hashing::mix::mix2(i, seed))
            .collect();
        let last_assignment = router.route_batch(&tracer_keys);
        Self {
            tracer_keys,
            state: Mutex::new(State {
                last_assignment,
                relocated: 0,
                violations: 0,
                epochs_observed: 0,
                last_relocated_frac: 0.0,
            }),
        }
    }

    /// Re-probe after a membership change. `changed_buckets` are the
    /// buckets that were removed/added in this epoch.
    pub fn observe_epoch(&self, router: &Router, changed_buckets: &[u32]) -> RebalanceSummary {
        let mut st = lock_recover(&self.state);
        let now = router.route_batch(&self.tracer_keys);
        let rep = audit::disruption(&st.last_assignment, &now, &self.tracer_keys, changed_buckets);
        st.relocated += rep.relocated as u64;
        st.violations += rep.collateral as u64;
        st.epochs_observed += 1;
        st.last_assignment = now;
        st.last_relocated_frac = rep.relocated as f64 / self.tracer_keys.len().max(1) as f64;
        router.metrics.relocated_keys.add(rep.relocated as u64);
        RebalanceSummary {
            epochs_observed: st.epochs_observed,
            relocated: st.relocated,
            violations: st.violations,
            last_relocated_frac: st.last_relocated_frac,
        }
    }

    /// Snapshot of the accumulated audit counters.
    pub fn summary(&self) -> RebalanceSummary {
        let st = lock_recover(&self.state);
        RebalanceSummary {
            epochs_observed: st.epochs_observed,
            relocated: st.relocated,
            violations: st.violations,
            last_relocated_frac: st.last_relocated_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;

    #[test]
    fn failure_relocates_about_one_wth() {
        let router = Router::new("memento", 10, 100, None).unwrap();
        let reb = Rebalancer::new(&router, 20_000, 0xAAA);
        router.fail_bucket(4).unwrap();
        let s = reb.observe_epoch(&router, &[4]);
        assert_eq!(s.violations, 0, "memento must have zero collateral movement");
        // ~1/10 of keys lived on bucket 4.
        assert!(
            (0.07..0.13).contains(&s.last_relocated_frac),
            "relocated {}",
            s.last_relocated_frac
        );
    }

    #[test]
    fn restore_is_monotone() {
        let router = Router::new("memento", 10, 100, None).unwrap();
        let reb = Rebalancer::new(&router, 20_000, 0xBBB);
        router.fail_bucket(2).unwrap();
        reb.observe_epoch(&router, &[2]);
        let (b, _) = router.add_node().unwrap();
        assert_eq!(b, 2);
        let s = reb.observe_epoch(&router, &[2]);
        assert_eq!(s.violations, 0, "restore must only move keys back to bucket 2");
        assert_eq!(s.epochs_observed, 2);
    }

    #[test]
    fn multiple_failures_accumulate() {
        let router = Router::new("memento", 20, 200, None).unwrap();
        let reb = Rebalancer::new(&router, 10_000, 0xCCC);
        for b in [3u32, 7, 11] {
            router.fail_bucket(b).unwrap();
            let s = reb.observe_epoch(&router, &[b]);
            assert_eq!(s.violations, 0);
        }
        let s = reb.summary();
        assert_eq!(s.epochs_observed, 3);
        assert!(s.relocated > 0);
        assert!(router.metrics.relocated_keys.get() > 0);
    }

    #[test]
    fn summary_reports_the_real_last_relocated_frac() {
        let router = Router::new("memento", 10, 100, None).unwrap();
        let reb = Rebalancer::new(&router, 20_000, 0xDDD);
        assert_eq!(reb.summary().last_relocated_frac, 0.0, "nothing observed yet");
        router.fail_bucket(6).unwrap();
        let observed = reb.observe_epoch(&router, &[6]);
        let summarized = reb.summary();
        assert!(observed.last_relocated_frac > 0.0);
        assert_eq!(
            summarized.last_relocated_frac, observed.last_relocated_frac,
            "summary must report the last epoch's fraction, not a hardcoded zero"
        );
    }
}
