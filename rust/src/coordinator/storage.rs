//! Simulated storage nodes: the cluster substrate behind the router.
//!
//! Each working bucket is backed by an in-process KV store. On membership
//! change the cluster *actually migrates* the affected keys, so the e2e
//! example measures real data movement and the rebalancer audits it against
//! the paper's minimal-disruption bound.
//!
//! Storage inside one node is **lock-sharded**: the record map is split
//! into [`StorageNode::SHARDS`] independently locked shards keyed by the
//! key's mixed hash, so concurrent PUT/GET traffic from many connection
//! threads contends per shard instead of serializing on one node-wide
//! `Mutex` (DESIGN.md §8). All locks follow the crate's recover-on-poison
//! policy ([`crate::sync::lock_recover`]).

use super::membership::NodeId;
use crate::sync::{lock_recover, read_recover, write_recover};
use std::collections::HashMap;
use std::sync::{Mutex, RwLock};

/// One simulated storage node.
#[derive(Debug)]
pub struct StorageNode {
    /// Record shards, indexed by the key's mixed hash.
    shards: Vec<Mutex<HashMap<u64, Vec<u8>>>>,
    /// GET counter (load measurement for the balance figures).
    pub gets: std::sync::atomic::AtomicU64,
    /// PUT counter.
    pub puts: std::sync::atomic::AtomicU64,
}

impl Default for StorageNode {
    fn default() -> Self {
        Self {
            shards: (0..Self::SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            gets: Default::default(),
            puts: Default::default(),
        }
    }
}

impl StorageNode {
    /// Lock shards per node. Power of two; 16 shards keep the expected
    /// contention probability for two concurrent ops at 1/16 while the
    /// per-node footprint stays trivial.
    pub const SHARDS: usize = 16;

    /// The shard a key lives in. Keys are mixed first: numeric protocol
    /// keys (`PUT 0..n`) are sequential, and the low bits of the raw key
    /// would put whole ranges in one shard.
    #[inline]
    fn shard_of(key: u64) -> usize {
        (crate::hashing::mix::splitmix64_mix(key) as usize) & (Self::SHARDS - 1)
    }

    /// Store a record.
    pub fn put(&self, key: u64, value: Vec<u8>) {
        self.puts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        lock_recover(&self.shards[Self::shard_of(key)]).insert(key, value);
    }

    /// Read a record.
    pub fn get(&self, key: u64) -> Option<Vec<u8>> {
        self.gets.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        lock_recover(&self.shards[Self::shard_of(key)]).get(&key).cloned()
    }

    /// Remove a record, returning its value.
    pub fn delete(&self, key: u64) -> Option<Vec<u8>> {
        lock_recover(&self.shards[Self::shard_of(key)]).remove(&key)
    }

    /// Number of stored records.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_recover(s).len()).sum()
    }

    /// Whether the node holds no records.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| lock_recover(s).is_empty())
    }

    /// Drain all records (node decommission / failure with handoff).
    pub fn drain(&self) -> Vec<(u64, Vec<u8>)> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(lock_recover(s).drain());
        }
        out
    }

    /// Keys only (cheaper than drain when planning migrations).
    pub fn keys(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(lock_recover(s).keys().copied());
        }
        out
    }

    /// Per-shard record counts (shard-balance measurement / tests).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| lock_recover(s).len()).collect()
    }

    /// `(gets, puts)` served so far — the observed-load figure the
    /// weighted-balance reporting (`NODES`, loadgen) compares against a
    /// node's configured weight share.
    pub fn op_counts(&self) -> (u64, u64) {
        (
            self.gets.load(std::sync::atomic::Ordering::Relaxed),
            self.puts.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Store a record only if the key is absent; returns whether it was
    /// stored. The migration executor relocates with this instead of
    /// [`StorageNode::put`]: a concurrent client PUT that already landed
    /// on the destination is strictly fresher than the copy in flight, so
    /// the relocated value must never clobber it.
    pub fn put_if_absent(&self, key: u64, value: Vec<u8>) -> bool {
        self.puts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut shard = lock_recover(&self.shards[Self::shard_of(key)]);
        match shard.entry(key) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(value);
                true
            }
        }
    }

    /// Keys of one shard only (bounded snapshot for batched migration
    /// planning — [`StorageNode::keys`] walks every shard).
    pub fn shard_keys(&self, shard: usize) -> Vec<u64> {
        lock_recover(&self.shards[shard]).keys().copied().collect()
    }

    /// Remove and return up to `limit` records of shard `shard` whose key
    /// satisfies `pred` (an `extract_if` in spirit; that std API is not
    /// stable in the offline toolchain). One shard lock is held for the
    /// scan, so concurrent traffic on the other shards proceeds; callers
    /// bound `limit` to keep the critical section short.
    pub fn extract_shard_if(
        &self,
        shard: usize,
        limit: usize,
        mut pred: impl FnMut(u64) -> bool,
    ) -> Vec<(u64, Vec<u8>)> {
        let mut guard = lock_recover(&self.shards[shard]);
        let picked: Vec<u64> = guard.keys().copied().filter(|&k| pred(k)).take(limit).collect();
        picked
            .into_iter()
            .map(|k| {
                let v = guard.remove(&k).expect("picked under the same lock");
                (k, v)
            })
            .collect()
    }
}

/// The fleet of storage nodes, keyed by stable node id.
#[derive(Debug, Default)]
pub struct StorageCluster {
    nodes: RwLock<HashMap<NodeId, std::sync::Arc<StorageNode>>>,
}

impl StorageCluster {
    /// An empty fleet.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the store for a node.
    pub fn node(&self, id: NodeId) -> std::sync::Arc<StorageNode> {
        if let Some(n) = read_recover(&self.nodes).get(&id) {
            return n.clone();
        }
        write_recover(&self.nodes)
            .entry(id)
            .or_insert_with(|| std::sync::Arc::new(StorageNode::default()))
            .clone()
    }

    /// Total records across the fleet.
    pub fn total_records(&self) -> usize {
        read_recover(&self.nodes).values().map(|n| n.len()).sum()
    }

    /// Per-node record counts (balance measurement).
    pub fn load_by_node(&self) -> Vec<(NodeId, usize)> {
        let mut v: Vec<(NodeId, usize)> =
            read_recover(&self.nodes).iter().map(|(id, n)| (*id, n.len())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Migrate every record of `from` using `placement` (key → target node);
    /// returns the number of records moved. Used on failure: the failed
    /// node's data is re-routed to the survivors.
    pub fn migrate_from(
        &self,
        from: NodeId,
        placement: impl Fn(u64) -> NodeId,
    ) -> usize {
        let src = self.node(from);
        let records = src.drain();
        let moved = records.len();
        for (k, v) in records {
            let dst = placement(k);
            debug_assert_ne!(dst, from, "placement must not target the failed node");
            self.node(dst).put(k, v);
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_kv_roundtrip() {
        let n = StorageNode::default();
        assert!(n.is_empty());
        n.put(1, b"a".to_vec());
        n.put(2, b"b".to_vec());
        assert_eq!(n.get(1), Some(b"a".to_vec()));
        assert_eq!(n.get(3), None);
        assert_eq!(n.delete(2), Some(b"b".to_vec()));
        assert_eq!(n.len(), 1);
        assert_eq!(n.gets.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(n.op_counts(), (2, 2), "2 gets, 2 puts");
    }

    #[test]
    fn cluster_creates_nodes_on_demand() {
        let c = StorageCluster::new();
        c.node(NodeId(5)).put(10, vec![1]);
        assert_eq!(c.total_records(), 1);
        assert_eq!(c.load_by_node(), vec![(NodeId(5), 1)]);
    }

    #[test]
    fn migration_moves_everything() {
        let c = StorageCluster::new();
        for k in 0..100u64 {
            c.node(NodeId(0)).put(k, vec![k as u8]);
        }
        let moved = c.migrate_from(NodeId(0), |k| NodeId(1 + (k % 3)));
        assert_eq!(moved, 100);
        assert_eq!(c.node(NodeId(0)).len(), 0);
        assert_eq!(c.total_records(), 100);
        // All three targets received some.
        for t in 1..=3u64 {
            assert!(c.node(NodeId(t)).len() > 20);
        }
    }

    #[test]
    fn shards_spread_sequential_keys() {
        let n = StorageNode::default();
        for k in 0..4096u64 {
            n.put(k, vec![0]);
        }
        let loads = n.shard_loads();
        assert_eq!(loads.len(), StorageNode::SHARDS);
        assert_eq!(loads.iter().sum::<usize>(), 4096);
        let mean = 4096 / StorageNode::SHARDS;
        for (i, l) in loads.iter().enumerate() {
            assert!(
                *l > mean / 2 && *l < mean * 2,
                "shard {i} holds {l} of 4096 records (mean {mean}): mixing failed"
            );
        }
    }

    #[test]
    fn drain_and_keys_cover_every_shard() {
        let n = StorageNode::default();
        for k in 0..512u64 {
            n.put(k, vec![k as u8]);
        }
        let mut keys = n.keys();
        keys.sort_unstable();
        assert_eq!(keys, (0..512).collect::<Vec<u64>>());
        let drained = n.drain();
        assert_eq!(drained.len(), 512);
        assert!(n.is_empty());
    }

    #[test]
    fn put_if_absent_never_clobbers() {
        let n = StorageNode::default();
        assert!(n.put_if_absent(1, b"migrated".to_vec()));
        n.put(2, b"fresh".to_vec());
        assert!(!n.put_if_absent(2, b"stale".to_vec()));
        assert_eq!(n.get(2), Some(b"fresh".to_vec()));
        assert_eq!(n.get(1), Some(b"migrated".to_vec()));
    }

    #[test]
    fn extract_shard_if_is_bounded_and_selective() {
        let n = StorageNode::default();
        for k in 0..512u64 {
            n.put(k, vec![k as u8]);
        }
        let mut extracted = Vec::new();
        for s in 0..StorageNode::SHARDS {
            // Pull even keys only, in batches of 8 per call.
            loop {
                let batch = n.extract_shard_if(s, 8, |k| k % 2 == 0);
                assert!(batch.len() <= 8);
                if batch.is_empty() {
                    break;
                }
                extracted.extend(batch);
            }
        }
        assert_eq!(extracted.len(), 256);
        for (k, v) in &extracted {
            assert_eq!(*k % 2, 0);
            assert_eq!(v, &vec![*k as u8]);
        }
        assert_eq!(n.len(), 256, "odd keys stay put");
        let mut keys = n.keys();
        keys.sort_unstable();
        assert!(keys.iter().all(|k| k % 2 == 1));
    }

    #[test]
    fn shard_keys_matches_full_key_walk() {
        let n = StorageNode::default();
        for k in 0..200u64 {
            n.put(k, vec![0]);
        }
        let mut union: Vec<u64> =
            (0..StorageNode::SHARDS).flat_map(|s| n.shard_keys(s)).collect();
        union.sort_unstable();
        let mut all = n.keys();
        all.sort_unstable();
        assert_eq!(union, all);
    }

    #[test]
    fn a_poisoned_shard_keeps_serving() {
        let n = std::sync::Arc::new(StorageNode::default());
        n.put(7, b"x".to_vec());
        let n2 = n.clone();
        let _ = std::thread::spawn(move || {
            // Poison the shard key 7 lives in while holding its lock.
            let _g = n2.shards[StorageNode::shard_of(7)].lock().unwrap();
            panic!("poison");
        })
        .join();
        assert_eq!(n.get(7), Some(b"x".to_vec()), "recover-on-poison policy");
        n.put(7, b"y".to_vec());
        assert_eq!(n.get(7), Some(b"y".to_vec()));
    }
}
