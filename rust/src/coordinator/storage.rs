//! Simulated storage nodes: the cluster substrate behind the router.
//!
//! Each working bucket is backed by an in-process KV store. On membership
//! change the cluster *actually migrates* the affected keys, so the e2e
//! example measures real data movement and the rebalancer audits it against
//! the paper's minimal-disruption bound.
//!
//! Storage inside one node is **lock-sharded**: the record map is split
//! into [`StorageNode::SHARDS`] independently locked shards keyed by the
//! key's mixed hash, so concurrent PUT/GET traffic from many connection
//! threads contends per shard instead of serializing on one node-wide
//! `Mutex` (DESIGN.md §8). All locks follow the crate's recover-on-poison
//! policy ([`crate::sync::lock_recover`]).
//!
//! A node is either **volatile** ([`StorageNode::default`], the original
//! in-memory substrate) or **durable** ([`StorageNode::durable`]): the
//! durable flavor logs every mutation to a per-shard write-ahead log
//! *before* the map changes and commits (group-commit fsync) after the
//! shard lock drops, so an acked write survives a crash (DESIGN.md §11).

use super::membership::NodeId;
use super::wal::{NodeWal, ReplayStats, StorageDurability, WalOptions};
use crate::metrics::WalMetrics;
use crate::sync::{lock_recover, read_recover, write_recover};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, RwLock};

/// One simulated storage node.
#[derive(Debug)]
pub struct StorageNode {
    /// Record shards, indexed by the key's mixed hash.
    shards: Vec<Mutex<HashMap<u64, Vec<u8>>>>,
    /// Write-ahead log (`None` = volatile node).
    wal: Option<NodeWal>,
    /// GET counter (load measurement for the balance figures).
    pub gets: std::sync::atomic::AtomicU64,
    /// PUT counter.
    pub puts: std::sync::atomic::AtomicU64,
}

impl Default for StorageNode {
    fn default() -> Self {
        Self {
            shards: (0..Self::SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            wal: None,
            gets: Default::default(),
            puts: Default::default(),
        }
    }
}

impl StorageNode {
    /// Lock shards per node. Power of two; 16 shards keep the expected
    /// contention probability for two concurrent ops at 1/16 while the
    /// per-node footprint stays trivial.
    pub const SHARDS: usize = 16;

    /// The shard a key lives in. Keys are mixed first: numeric protocol
    /// keys (`PUT 0..n`) are sequential, and the low bits of the raw key
    /// would put whole ranges in one shard.
    #[inline]
    fn shard_of(key: u64) -> usize {
        (crate::hashing::mix::splitmix64_mix(key) as usize) & (Self::SHARDS - 1)
    }

    /// Open a durable node rooted at `dir`: replay its WAL + snapshots
    /// into the shard maps and keep logging from here on. Returns the
    /// node alongside what replay found.
    pub fn durable(
        dir: &Path,
        opts: WalOptions,
        metrics: Arc<WalMetrics>,
    ) -> crate::Result<(Self, ReplayStats)> {
        let (wal, maps, stats) = NodeWal::open(dir, opts, metrics)?;
        Ok((
            Self {
                shards: maps.into_iter().map(Mutex::new).collect(),
                wal: Some(wal),
                gets: Default::default(),
                puts: Default::default(),
            },
            stats,
        ))
    }

    /// Whether mutations are WAL-backed.
    pub fn is_durable(&self) -> bool {
        self.wal.is_some()
    }

    /// Auto-compact shard `s` if its log outgrew the configured
    /// threshold. Call with the shard map lock held (the snapshot must
    /// match the exact state the log prefix produced).
    fn maybe_compact(&self, s: usize, guard: &HashMap<u64, Vec<u8>>) -> bool {
        match &self.wal {
            Some(w) if w.compact_threshold() > 0 && w.shard_bytes(s) >= w.compact_threshold() => {
                w.compact_shard(s, guard);
                true
            }
            _ => false,
        }
    }

    /// Store a record. On a durable node the WAL record is written
    /// before the map mutates and fsynced (per policy) before returning,
    /// so returning *is* the durability ack.
    pub fn put(&self, key: u64, value: Vec<u8>) {
        self.puts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let s = Self::shard_of(key);
        let t_lock = crate::obs::timer(crate::obs::Stage::ShardLockWait);
        let mut guard = lock_recover(&self.shards[s]);
        drop(t_lock);
        let seq = match &self.wal {
            Some(w) => {
                let t_append = crate::obs::timer(crate::obs::Stage::WalAppend);
                let seq = w.append_put(s, key, &value);
                drop(t_append);
                Some(seq)
            }
            None => None,
        };
        guard.insert(key, value);
        // Compaction fsyncs the snapshot, which covers the new record.
        let compacted = self.maybe_compact(s, &guard);
        drop(guard);
        if let (Some(w), Some(seq)) = (&self.wal, seq) {
            if !compacted {
                let t_sync = crate::obs::timer(crate::obs::Stage::FsyncWait);
                w.commit(s, seq);
                drop(t_sync);
            }
        }
    }

    /// Read a record.
    pub fn get(&self, key: u64) -> Option<Vec<u8>> {
        self.gets.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        lock_recover(&self.shards[Self::shard_of(key)]).get(&key).cloned()
    }

    /// Remove a record, returning its value.
    pub fn delete(&self, key: u64) -> Option<Vec<u8>> {
        let s = Self::shard_of(key);
        let mut guard = lock_recover(&self.shards[s]);
        let seq = self.wal.as_ref().map(|w| w.append_del(s, key));
        let prev = guard.remove(&key);
        drop(guard);
        if let (Some(w), Some(seq)) = (&self.wal, seq) {
            w.commit(s, seq);
        }
        prev
    }

    /// Number of stored records.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_recover(s).len()).sum()
    }

    /// Whether the node holds no records.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| lock_recover(s).is_empty())
    }

    /// Drain all records (node decommission / failure with handoff). On
    /// a durable node each emptied shard is compacted to an empty
    /// snapshot — one atomic, fsynced write per shard instead of a
    /// delete record per key.
    pub fn drain(&self) -> Vec<(u64, Vec<u8>)> {
        let mut out = Vec::new();
        for (s, m) in self.shards.iter().enumerate() {
            let mut guard = lock_recover(m);
            out.extend(guard.drain());
            if let Some(w) = &self.wal {
                w.compact_shard(s, &guard);
            }
        }
        out
    }

    /// Keys only (cheaper than drain when planning migrations).
    pub fn keys(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(lock_recover(s).keys().copied());
        }
        out
    }

    /// Per-shard record counts (shard-balance measurement / tests).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| lock_recover(s).len()).collect()
    }

    /// `(gets, puts)` served so far — the observed-load figure the
    /// weighted-balance reporting (`NODES`, loadgen) compares against a
    /// node's configured weight share.
    pub fn op_counts(&self) -> (u64, u64) {
        (
            self.gets.load(std::sync::atomic::Ordering::Relaxed),
            self.puts.load(std::sync::atomic::Ordering::Relaxed),
        )
    }

    /// Store a record only if the key is absent; returns whether it was
    /// stored. The migration executor relocates with this instead of
    /// [`StorageNode::put`]: a concurrent client PUT that already landed
    /// on the destination is strictly fresher than the copy in flight, so
    /// the relocated value must never clobber it.
    pub fn put_if_absent(&self, key: u64, value: Vec<u8>) -> bool {
        self.puts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let s = Self::shard_of(key);
        let t_lock = crate::obs::timer(crate::obs::Stage::ShardLockWait);
        let mut shard = lock_recover(&self.shards[s]);
        drop(t_lock);
        if shard.contains_key(&key) {
            return false;
        }
        let seq = match &self.wal {
            Some(w) => {
                let t_append = crate::obs::timer(crate::obs::Stage::WalAppend);
                let seq = w.append_put(s, key, &value);
                drop(t_append);
                Some(seq)
            }
            None => None,
        };
        shard.insert(key, value);
        let compacted = self.maybe_compact(s, &shard);
        drop(shard);
        if let (Some(w), Some(seq)) = (&self.wal, seq) {
            if !compacted {
                let t_sync = crate::obs::timer(crate::obs::Stage::FsyncWait);
                w.commit(s, seq);
                drop(t_sync);
            }
        }
        true
    }

    /// Keys of one shard only (bounded snapshot for batched migration
    /// planning — [`StorageNode::keys`] walks every shard).
    pub fn shard_keys(&self, shard: usize) -> Vec<u64> {
        lock_recover(&self.shards[shard]).keys().copied().collect()
    }

    /// Remove and return up to `limit` records of shard `shard` whose key
    /// satisfies `pred` (an `extract_if` in spirit; that std API is not
    /// stable in the offline toolchain). One shard lock is held for the
    /// scan, so concurrent traffic on the other shards proceeds; callers
    /// bound `limit` to keep the critical section short.
    pub fn extract_shard_if(
        &self,
        shard: usize,
        limit: usize,
        mut pred: impl FnMut(u64) -> bool,
    ) -> Vec<(u64, Vec<u8>)> {
        let mut guard = lock_recover(&self.shards[shard]);
        let picked: Vec<u64> = guard.keys().copied().filter(|&k| pred(k)).take(limit).collect();
        let mut last_seq = None;
        if let Some(w) = &self.wal {
            for &k in &picked {
                last_seq = Some(w.append_del(shard, k));
            }
        }
        let out: Vec<(u64, Vec<u8>)> = picked
            .into_iter()
            .map(|k| {
                let v = guard.remove(&k).expect("picked under the same lock");
                (k, v)
            })
            .collect();
        drop(guard);
        if let (Some(w), Some(seq)) = (&self.wal, last_seq) {
            w.commit(shard, seq);
        }
        out
    }

    /// Fsync every shard log with unsynced records; returns files synced
    /// (0 on a volatile node).
    pub fn sync(&self) -> usize {
        self.wal.as_ref().map_or(0, |w| w.sync_all())
    }

    /// Compact every shard to a snapshot (explicit `COMPACT`); no-op on
    /// a volatile node.
    pub fn compact(&self) {
        if let Some(w) = &self.wal {
            for (s, m) in self.shards.iter().enumerate() {
                let guard = lock_recover(m);
                w.compact_shard(s, &guard);
            }
        }
    }

    /// Order-independent digest of one shard's contents (keys sorted,
    /// values folded in). Two nodes hold identical shard state iff the
    /// digests match — the recovery-idempotence tests compare these
    /// across repeated replays.
    pub fn shard_digest(&self, shard: usize) -> u64 {
        let guard = lock_recover(&self.shards[shard]);
        let mut keys: Vec<u64> = guard.keys().copied().collect();
        keys.sort_unstable();
        let mut h = 0x9E37_79B9_7F4A_7C15u64 ^ keys.len() as u64;
        for k in keys {
            h = crate::hashing::xxhash::xxhash64(&k.to_le_bytes(), h);
            h = crate::hashing::xxhash::xxhash64(&guard[&k], h);
        }
        h
    }

    /// Digest of the whole node (all shards, fixed order).
    pub fn content_digest(&self) -> u64 {
        let mut h = 0u64;
        for s in 0..Self::SHARDS {
            h = crate::hashing::xxhash::xxhash64(&self.shard_digest(s).to_le_bytes(), h);
        }
        h
    }
}

/// The fleet of storage nodes, keyed by stable node id.
#[derive(Debug, Default)]
pub struct StorageCluster {
    nodes: RwLock<HashMap<NodeId, std::sync::Arc<StorageNode>>>,
    /// When set, nodes open as durable stores under `root/node-<id>`.
    durability: Option<StorageDurability>,
}

impl StorageCluster {
    /// An empty fleet.
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a durable fleet rooted at `durability.root`: every existing
    /// `node-<id>` directory is replayed eagerly (so recovery sees all
    /// surviving data, not just nodes the first requests happen to
    /// touch); nodes created later open their own WAL directory lazily.
    pub fn durable(durability: StorageDurability) -> crate::Result<(Self, ReplayStats)> {
        std::fs::create_dir_all(&durability.root)
            .map_err(|e| crate::err!("create data dir {}: {e}", durability.root.display()))?;
        let mut nodes = HashMap::new();
        let mut stats = ReplayStats::default();
        let entries = std::fs::read_dir(&durability.root)
            .map_err(|e| crate::err!("scan data dir {}: {e}", durability.root.display()))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| crate::err!("scan {}: {e}", durability.root.display()))?;
            let name = entry.file_name();
            let Some(id) = name
                .to_str()
                .and_then(|n| n.strip_prefix("node-"))
                .and_then(|n| n.parse::<u64>().ok())
            else {
                continue;
            };
            let (node, st) = StorageNode::durable(
                &entry.path(),
                durability.opts,
                durability.metrics.clone(),
            )?;
            stats.merge(st);
            nodes.insert(NodeId(id), std::sync::Arc::new(node));
        }
        Ok((Self { nodes: RwLock::new(nodes), durability: Some(durability) }, stats))
    }

    /// Whether this fleet persists.
    pub fn is_durable(&self) -> bool {
        self.durability.is_some()
    }

    /// Get-or-create the store for a node. On a durable fleet a fresh
    /// node opens its WAL directory; an I/O failure there panics (the
    /// caller was promised a durable store — see the WAL's fsync-panic
    /// policy).
    pub fn node(&self, id: NodeId) -> std::sync::Arc<StorageNode> {
        if let Some(n) = read_recover(&self.nodes).get(&id) {
            return n.clone();
        }
        write_recover(&self.nodes)
            .entry(id)
            .or_insert_with(|| match &self.durability {
                None => std::sync::Arc::new(StorageNode::default()),
                Some(d) => {
                    let dir = d.root.join(format!("{id}"));
                    let (node, _stats) = StorageNode::durable(&dir, d.opts, d.metrics.clone())
                        .unwrap_or_else(|e| {
                            panic!("open durable store {}: {e}", dir.display())
                        });
                    std::sync::Arc::new(node)
                }
            })
            .clone()
    }

    /// Snapshot of the fleet, sorted by node id.
    pub fn nodes(&self) -> Vec<(NodeId, std::sync::Arc<StorageNode>)> {
        let mut v: Vec<_> =
            read_recover(&self.nodes).iter().map(|(id, n)| (*id, n.clone())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Fsync every node's unsynced shard logs; returns files synced.
    pub fn sync_all(&self) -> usize {
        self.nodes().iter().map(|(_id, n)| n.sync()).sum()
    }

    /// Compact every node's shards to snapshots.
    pub fn compact_all(&self) {
        for (_id, n) in self.nodes() {
            n.compact();
        }
    }

    /// Total records across the fleet.
    pub fn total_records(&self) -> usize {
        read_recover(&self.nodes).values().map(|n| n.len()).sum()
    }

    /// Per-node record counts (balance measurement).
    pub fn load_by_node(&self) -> Vec<(NodeId, usize)> {
        let mut v: Vec<(NodeId, usize)> =
            read_recover(&self.nodes).iter().map(|(id, n)| (*id, n.len())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Migrate every record of `from` using `placement` (key → target node);
    /// returns the number of records moved. Used on failure: the failed
    /// node's data is re-routed to the survivors.
    pub fn migrate_from(
        &self,
        from: NodeId,
        placement: impl Fn(u64) -> NodeId,
    ) -> usize {
        let src = self.node(from);
        let records = src.drain();
        let moved = records.len();
        for (k, v) in records {
            let dst = placement(k);
            debug_assert_ne!(dst, from, "placement must not target the failed node");
            self.node(dst).put(k, v);
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_kv_roundtrip() {
        let n = StorageNode::default();
        assert!(n.is_empty());
        n.put(1, b"a".to_vec());
        n.put(2, b"b".to_vec());
        assert_eq!(n.get(1), Some(b"a".to_vec()));
        assert_eq!(n.get(3), None);
        assert_eq!(n.delete(2), Some(b"b".to_vec()));
        assert_eq!(n.len(), 1);
        assert_eq!(n.gets.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(n.op_counts(), (2, 2), "2 gets, 2 puts");
    }

    #[test]
    fn cluster_creates_nodes_on_demand() {
        let c = StorageCluster::new();
        c.node(NodeId(5)).put(10, vec![1]);
        assert_eq!(c.total_records(), 1);
        assert_eq!(c.load_by_node(), vec![(NodeId(5), 1)]);
    }

    #[test]
    fn migration_moves_everything() {
        let c = StorageCluster::new();
        for k in 0..100u64 {
            c.node(NodeId(0)).put(k, vec![k as u8]);
        }
        let moved = c.migrate_from(NodeId(0), |k| NodeId(1 + (k % 3)));
        assert_eq!(moved, 100);
        assert_eq!(c.node(NodeId(0)).len(), 0);
        assert_eq!(c.total_records(), 100);
        // All three targets received some.
        for t in 1..=3u64 {
            assert!(c.node(NodeId(t)).len() > 20);
        }
    }

    #[test]
    fn shards_spread_sequential_keys() {
        let n = StorageNode::default();
        for k in 0..4096u64 {
            n.put(k, vec![0]);
        }
        let loads = n.shard_loads();
        assert_eq!(loads.len(), StorageNode::SHARDS);
        assert_eq!(loads.iter().sum::<usize>(), 4096);
        let mean = 4096 / StorageNode::SHARDS;
        for (i, l) in loads.iter().enumerate() {
            assert!(
                *l > mean / 2 && *l < mean * 2,
                "shard {i} holds {l} of 4096 records (mean {mean}): mixing failed"
            );
        }
    }

    #[test]
    fn drain_and_keys_cover_every_shard() {
        let n = StorageNode::default();
        for k in 0..512u64 {
            n.put(k, vec![k as u8]);
        }
        let mut keys = n.keys();
        keys.sort_unstable();
        assert_eq!(keys, (0..512).collect::<Vec<u64>>());
        let drained = n.drain();
        assert_eq!(drained.len(), 512);
        assert!(n.is_empty());
    }

    #[test]
    fn put_if_absent_never_clobbers() {
        let n = StorageNode::default();
        assert!(n.put_if_absent(1, b"migrated".to_vec()));
        n.put(2, b"fresh".to_vec());
        assert!(!n.put_if_absent(2, b"stale".to_vec()));
        assert_eq!(n.get(2), Some(b"fresh".to_vec()));
        assert_eq!(n.get(1), Some(b"migrated".to_vec()));
    }

    #[test]
    fn extract_shard_if_is_bounded_and_selective() {
        let n = StorageNode::default();
        for k in 0..512u64 {
            n.put(k, vec![k as u8]);
        }
        let mut extracted = Vec::new();
        for s in 0..StorageNode::SHARDS {
            // Pull even keys only, in batches of 8 per call.
            loop {
                let batch = n.extract_shard_if(s, 8, |k| k % 2 == 0);
                assert!(batch.len() <= 8);
                if batch.is_empty() {
                    break;
                }
                extracted.extend(batch);
            }
        }
        assert_eq!(extracted.len(), 256);
        for (k, v) in &extracted {
            assert_eq!(*k % 2, 0);
            assert_eq!(v, &vec![*k as u8]);
        }
        assert_eq!(n.len(), 256, "odd keys stay put");
        let mut keys = n.keys();
        keys.sort_unstable();
        assert!(keys.iter().all(|k| k % 2 == 1));
    }

    #[test]
    fn shard_keys_matches_full_key_walk() {
        let n = StorageNode::default();
        for k in 0..200u64 {
            n.put(k, vec![0]);
        }
        let mut union: Vec<u64> =
            (0..StorageNode::SHARDS).flat_map(|s| n.shard_keys(s)).collect();
        union.sort_unstable();
        let mut all = n.keys();
        all.sort_unstable();
        assert_eq!(union, all);
    }

    #[test]
    fn durable_node_survives_reopen_with_identical_digest() {
        let dir = std::env::temp_dir()
            .join(format!("memento-storage-durable-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let metrics = Arc::new(WalMetrics::new());
        let digest = {
            let (n, stats) =
                StorageNode::durable(&dir, WalOptions::default(), metrics.clone()).unwrap();
            assert_eq!(stats, ReplayStats::default());
            assert!(n.is_durable());
            for k in 0..100u64 {
                n.put(k, format!("v{k}").into_bytes());
            }
            assert!(n.put_if_absent(200, b"pia".to_vec()));
            assert!(!n.put_if_absent(200, b"clobber".to_vec()));
            n.delete(3);
            n.content_digest()
        };
        let (n2, stats) =
            StorageNode::durable(&dir, WalOptions::default(), metrics).unwrap();
        assert_eq!(n2.len(), 100, "100 puts + 1 put_if_absent - 1 delete");
        assert_eq!(n2.get(7), Some(b"v7".to_vec()));
        assert_eq!(n2.get(200), Some(b"pia".to_vec()));
        assert_eq!(n2.get(3), None, "delete replayed");
        assert_eq!(n2.content_digest(), digest, "replay reproduces state exactly");
        assert_eq!(
            stats.wal_records, 102,
            "100 puts + 1 accepted put_if_absent + 1 del (the rejected pia logs nothing)"
        );
        drop(n2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_cluster_scans_node_dirs_eagerly() {
        let root = std::env::temp_dir()
            .join(format!("memento-storage-cluster-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let d = || StorageDurability {
            root: root.clone(),
            opts: WalOptions::default(),
            metrics: Arc::new(WalMetrics::new()),
        };
        {
            let (c, _stats) = StorageCluster::durable(d()).unwrap();
            c.node(NodeId(1)).put(10, b"one".to_vec());
            c.node(NodeId(4)).put(11, b"four".to_vec());
            assert!(c.is_durable());
        }
        let (c2, stats) = StorageCluster::durable(d()).unwrap();
        assert_eq!(stats.wal_records, 2);
        let ids: Vec<NodeId> = c2.nodes().iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![NodeId(1), NodeId(4)], "eager scan, sorted");
        assert_eq!(c2.node(NodeId(1)).get(10), Some(b"one".to_vec()));
        assert_eq!(c2.node(NodeId(4)).get(11), Some(b"four".to_vec()));
        assert_eq!(c2.sync_all(), 0, "everything replayed is already durable");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn auto_compaction_triggers_on_log_growth() {
        let dir = std::env::temp_dir()
            .join(format!("memento-storage-autocompact-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let metrics = Arc::new(WalMetrics::new());
        let opts = WalOptions { compact_bytes: 256, ..WalOptions::default() };
        {
            let (n, _s) = StorageNode::durable(&dir, opts, metrics.clone()).unwrap();
            for k in 0..600u64 {
                n.put(k, vec![0u8; 16]);
            }
        }
        assert!(metrics.snapshots.get() > 0, "256-byte threshold must have tripped");
        let (n2, stats) = StorageNode::durable(&dir, opts, metrics).unwrap();
        assert_eq!(n2.len(), 600);
        assert!(stats.snapshot_records > 0, "reopen loads from snapshots");
        drop(n2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_poisoned_shard_keeps_serving() {
        let n = std::sync::Arc::new(StorageNode::default());
        n.put(7, b"x".to_vec());
        let n2 = n.clone();
        let _ = std::thread::spawn(move || {
            // Poison the shard key 7 lives in while holding its lock.
            let _g = n2.shards[StorageNode::shard_of(7)].lock().unwrap();
            panic!("poison");
        })
        .join();
        assert_eq!(n.get(7), Some(b"x".to_vec()), "recover-on-poison policy");
        n.put(7, b"y".to_vec());
        assert_eq!(n.get(7), Some(b"y".to_vec()));
    }
}
