//! Simulated storage nodes: the cluster substrate behind the router.
//!
//! Each working bucket is backed by an in-process KV store. On membership
//! change the cluster *actually migrates* the affected keys, so the e2e
//! example measures real data movement and the rebalancer audits it against
//! the paper's minimal-disruption bound.

use super::membership::NodeId;
use std::collections::HashMap;
use std::sync::{Mutex, RwLock};

/// One simulated storage node.
#[derive(Debug, Default)]
pub struct StorageNode {
    data: Mutex<HashMap<u64, Vec<u8>>>,
    /// GET counter (load measurement for the balance figures).
    pub gets: std::sync::atomic::AtomicU64,
    /// PUT counter.
    pub puts: std::sync::atomic::AtomicU64,
}

impl StorageNode {
    /// Store a record.
    pub fn put(&self, key: u64, value: Vec<u8>) {
        self.puts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.data.lock().unwrap().insert(key, value);
    }

    /// Read a record.
    pub fn get(&self, key: u64) -> Option<Vec<u8>> {
        self.gets.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.data.lock().unwrap().get(&key).cloned()
    }

    /// Remove a record, returning its value.
    pub fn delete(&self, key: u64) -> Option<Vec<u8>> {
        self.data.lock().unwrap().remove(&key)
    }

    /// Number of stored records.
    pub fn len(&self) -> usize {
        self.data.lock().unwrap().len()
    }

    /// Whether the node holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain all records (node decommission / failure with handoff).
    pub fn drain(&self) -> Vec<(u64, Vec<u8>)> {
        self.data.lock().unwrap().drain().collect()
    }

    /// Keys only (cheaper than drain when planning migrations).
    pub fn keys(&self) -> Vec<u64> {
        self.data.lock().unwrap().keys().copied().collect()
    }
}

/// The fleet of storage nodes, keyed by stable node id.
#[derive(Debug, Default)]
pub struct StorageCluster {
    nodes: RwLock<HashMap<NodeId, std::sync::Arc<StorageNode>>>,
}

impl StorageCluster {
    /// An empty fleet.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the store for a node.
    pub fn node(&self, id: NodeId) -> std::sync::Arc<StorageNode> {
        if let Some(n) = self.nodes.read().unwrap().get(&id) {
            return n.clone();
        }
        self.nodes
            .write()
            .unwrap()
            .entry(id)
            .or_insert_with(|| std::sync::Arc::new(StorageNode::default()))
            .clone()
    }

    /// Total records across the fleet.
    pub fn total_records(&self) -> usize {
        self.nodes.read().unwrap().values().map(|n| n.len()).sum()
    }

    /// Per-node record counts (balance measurement).
    pub fn load_by_node(&self) -> Vec<(NodeId, usize)> {
        let mut v: Vec<(NodeId, usize)> =
            self.nodes.read().unwrap().iter().map(|(id, n)| (*id, n.len())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Migrate every record of `from` using `placement` (key → target node);
    /// returns the number of records moved. Used on failure: the failed
    /// node's data is re-routed to the survivors.
    pub fn migrate_from(
        &self,
        from: NodeId,
        placement: impl Fn(u64) -> NodeId,
    ) -> usize {
        let src = self.node(from);
        let records = src.drain();
        let moved = records.len();
        for (k, v) in records {
            let dst = placement(k);
            debug_assert_ne!(dst, from, "placement must not target the failed node");
            self.node(dst).put(k, v);
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_kv_roundtrip() {
        let n = StorageNode::default();
        assert!(n.is_empty());
        n.put(1, b"a".to_vec());
        n.put(2, b"b".to_vec());
        assert_eq!(n.get(1), Some(b"a".to_vec()));
        assert_eq!(n.get(3), None);
        assert_eq!(n.delete(2), Some(b"b".to_vec()));
        assert_eq!(n.len(), 1);
        assert_eq!(n.gets.load(std::sync::atomic::Ordering::Relaxed), 2);
    }

    #[test]
    fn cluster_creates_nodes_on_demand() {
        let c = StorageCluster::new();
        c.node(NodeId(5)).put(10, vec![1]);
        assert_eq!(c.total_records(), 1);
        assert_eq!(c.load_by_node(), vec![(NodeId(5), 1)]);
    }

    #[test]
    fn migration_moves_everything() {
        let c = StorageCluster::new();
        for k in 0..100u64 {
            c.node(NodeId(0)).put(k, vec![k as u8]);
        }
        let moved = c.migrate_from(NodeId(0), |k| NodeId(1 + (k % 3)));
        assert_eq!(moved, 100);
        assert_eq!(c.node(NodeId(0)).len(), 0);
        assert_eq!(c.total_records(), 100);
        // All three targets received some.
        for t in 1..=3u64 {
            assert!(c.node(NodeId(t)).len() > 20);
        }
    }
}
