//! # memento — MementoHash consistent hashing, reproduced end to end
//!
//! This crate reproduces *MementoHash: A Stateful, Minimal Memory, Best
//! Performing Consistent Hash Algorithm* (Coluzzi, Brocco, Antonucci, Leidi;
//! 2023) as a deployable system:
//!
//! * [`algorithms`] — the paper's algorithm (Memento, §V–VII) together with
//!   every baseline it is evaluated against (Jump, Anchor, Dx) and the
//!   related-work algorithms it surveys (Ring, Rendezvous, Maglev,
//!   MultiProbe), all behind the [`algorithms::ConsistentHasher`] trait.
//! * [`hashing`] — the non-consistent hash functions (Note III.1), PRNGs and
//!   workload key generators everything else is built on.
//! * [`coordinator`] — an epoch-versioned cluster-membership + request-router
//!   layer (the L3 system contribution): dynamic batching, failure handling,
//!   rebalance auditing, and a TCP front-end.
//! * [`cluster`] — the multi-process cluster: `memento node` child
//!   processes supervised by a pid/port-owning manager, a heartbeat
//!   failure detector (`Alive → Suspect → Dead` with flap suppression)
//!   that drives `KILLN`/rejoin automatically, and the end-to-end fault
//!   drill behind `BENCH_cluster.json`.
//! * [`runtime`] — the batched-lookup engine: a pure-Rust lockstep-lane
//!   backend by default, with the PJRT path (AOT-compiled JAX/Pallas
//!   artifacts, `artifacts/*.hlo.txt`) behind the `pjrt` cargo feature;
//!   python is build-time only.
//! * [`simulator`] — the paper's benchmark tool: scenarios (stable, one-shot
//!   removals, incremental removals, a/w sensitivity), exact memory
//!   accounting and balance/disruption/monotonicity auditors.
//! * [`loadgen`] — the traffic subsystem: closed/open-loop generation with
//!   coordinated-omission correction, pluggable workloads, mid-run churn
//!   injection, and merged latency/throughput reports — the paper's
//!   scenarios measured through the whole serving stack.
//! * [`sync`] — concurrency substrates: epoch-published snapshots behind
//!   the router's wait-free lookup path ([`sync::epoch::EpochPtr`]) and the
//!   crate-wide recover-on-poison lock policy.
//! * [`obs`] — the observability layer: a metrics registry with
//!   Prometheus-style exposition (`METRICS`/`MSAMPLE`/`SERIES`), sampled
//!   per-stage latency spans (`STAGES`) and an always-on flight recorder
//!   with dump-on-panic (`DUMP`).
//! * [`error`], [`benchkit`], [`testkit`], [`config`], [`cli`], [`metrics`],
//!   [`netserver`] — substrates built from scratch for the offline
//!   environment (no anyhow/criterion/proptest/tokio/serde/clap available).
//!
//! See `README.md` for the quickstart and layer map, `DESIGN.md` for the
//! per-experiment index mapping every figure and table of the paper to a
//! bench target, and `EXPERIMENTS.md` for how to run the benches and where
//! results land.

#![warn(missing_docs)]

pub mod algorithms;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod hashing;
pub mod loadgen;
pub mod metrics;
pub mod netserver;
pub mod obs;
pub mod proto;
pub mod runtime;
pub mod simulator;
pub mod sync;
pub mod testkit;

pub use error::{Error, Result};
