//! `loadgen` — a multi-threaded traffic subsystem that measures the
//! cluster under fire.
//!
//! The paper evaluates lookup speed with single-threaded microbenchmarks;
//! this module measures the *system* — TCP front-end → router → storage —
//! under production-shaped traffic:
//!
//! * **closed-loop** ([`Mode::Closed`]): N workers issue back-to-back
//!   requests, measuring the service's saturation throughput;
//! * **open-loop** ([`Mode::Open`]): arrivals are paced on a fixed
//!   schedule with coordinated-omission correction (see [`pacing`]), the
//!   honest way to measure tail latency at a target rate;
//! * pluggable [`workload`]s (uniform / Zipf / hot-set, GET/PUT mix);
//! * a [`churn`] injector that fails and restores nodes mid-run, so the
//!   paper's stable / one-shot / incremental scenarios run end-to-end;
//! * per-thread [`crate::metrics::Histogram`]s merged into a
//!   [`report::RunReport`] with p50/p99/p999, a per-second availability
//!   trajectory (the success-rate dip a fault drill gates on), and
//!   JSON/CSV output.
//!
//! Traffic reaches the service through a [`target::Target`] — either
//! in-process (no protocol overhead) or over live TCP — one per worker.

pub mod churn;
pub mod pacing;
pub mod report;
pub mod target;
pub mod workload;

pub use churn::{ChurnAction, ChurnEvent, ChurnScenario};
pub use report::{NodeLoad, RunReport, StageSnap, TimeSample, WorkerStats};
pub use target::{Target, TargetFactory};
pub use workload::{Op, Workload, ZipfTable};

use crate::hashing::prng::Xoshiro256;
use pacing::OpenLoopPacer;
use std::time::{Duration, Instant};

/// Generator mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// Back-to-back requests per worker (saturation measurement).
    Closed,
    /// Paced arrivals at `rate` ops/s total, CO-corrected (tail-latency
    /// measurement).
    Open {
        /// Target arrival rate in ops/s across all workers.
        rate: f64,
    },
}

impl Mode {
    /// Build by CLI name: `closed`, or `open` with a total rate.
    pub fn by_name(name: &str, rate: f64) -> Result<Self, String> {
        match name {
            "closed" => Ok(Mode::Closed),
            "open" => {
                if !rate.is_finite() || rate <= 0.0 {
                    return Err(format!("open-loop rate must be a positive number, got {rate}"));
                }
                Ok(Mode::Open { rate })
            }
            other => Err(format!("unknown mode '{other}' (closed|open)")),
        }
    }

    /// The mode's display name.
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Closed => "closed",
            Mode::Open { .. } => "open",
        }
    }
}

/// One run's configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Closed- or open-loop generation.
    pub mode: Mode,
    /// Traffic shape.
    pub workload: Workload,
    /// Worker thread count.
    pub threads: usize,
    /// Scheduled run length (open-loop backlog may drain past it).
    pub duration: Duration,
    /// Membership churn fired during the run.
    pub churn: ChurnScenario,
    /// Bucket ids the churn injector may probe for `KILL` (the initial
    /// cluster size).
    pub cluster_buckets: u32,
    /// Seed for the per-worker key/op streams.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            mode: Mode::Closed,
            workload: Workload::uniform(100_000, 0.7),
            threads: 4,
            duration: Duration::from_secs(2),
            churn: ChurnScenario::Stable,
            cluster_buckets: 16,
            seed: 7,
        }
    }
}

/// Lines per pipelined [`Target::call_many`] batch during preload.
const PRELOAD_BATCH: usize = 256;

/// Write keys `0..n` through fresh targets so read traffic hits existing
/// data; returns the number of acknowledged PUTs. Larger preloads are
/// striped across a few parallel connections, and each connection
/// pipelines `PRELOAD_BATCH`-line batches — serially, 10k loopback
/// round trips would cost most of a second of unmeasured startup time.
pub fn preload(factory: &TargetFactory, n: u64) -> Result<u64, String> {
    let conns: u64 = if n >= 1_000 { 4 } else { 1 };
    let mut loaders = Vec::with_capacity(conns as usize);
    for c in 0..conns {
        let mut t = factory().map_err(|e| format!("preload target: {e}"))?;
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-preload-{c}"))
            .spawn(move || -> Result<u64, String> {
                let mut ok = 0u64;
                let mut k = c;
                let mut batch = Vec::with_capacity(PRELOAD_BATCH);
                while k < n {
                    batch.clear();
                    while k < n && batch.len() < PRELOAD_BATCH {
                        batch.push(Op::Put(k).to_line());
                        k += conns;
                    }
                    let resps =
                        t.call_many(&batch).map_err(|e| format!("preload: {e}"))?;
                    ok += resps.iter().filter(|r| r.starts_with("OK")).count() as u64;
                }
                Ok(ok)
            })
            .map_err(|e| format!("spawn preloader {c}: {e}"))?;
        loaders.push(handle);
    }
    let mut total = 0u64;
    for h in loaders {
        total += h.join().map_err(|_| "a preloader panicked".to_string())??;
    }
    Ok(total)
}

/// Run one load test: spawn workers (and the churn injector if the
/// scenario has one), drive traffic until the schedule ends, merge every
/// thread's histograms and return the report.
pub fn run(cfg: &LoadgenConfig, factory: &TargetFactory) -> Result<RunReport, String> {
    let threads = cfg.threads.max(1);
    // Open every connection up front so a refused target fails the run
    // before any traffic is sent.
    let mut targets = Vec::with_capacity(threads);
    for _ in 0..threads {
        targets.push(factory().map_err(|e| format!("worker target: {e}"))?);
    }
    let plan = cfg.churn.plan(cfg.duration);
    let churn_admin = if plan.is_empty() {
        None
    } else {
        Some(factory().map_err(|e| format!("churn target: {e}"))?)
    };
    // The scraper's own connection — best-effort: a target that cannot
    // open one more connection costs the time series, not the run.
    let scrape_admin = factory().ok();

    let start = Instant::now();
    let mut workers = Vec::with_capacity(threads);
    for (w, tgt) in targets.into_iter().enumerate() {
        let workload = cfg.workload.clone();
        let duration = cfg.duration;
        // Each worker paces 1/threads of the rate, phase-shifted so the
        // combined stream is uniform rather than `threads`-sized bursts.
        let pacer = match cfg.mode {
            Mode::Open { rate } => {
                let p = OpenLoopPacer::with_rate(start, rate / threads as f64);
                let phase = p.interval_ns() * w as u64 / threads as u64;
                Some(p.with_phase(phase))
            }
            Mode::Closed => None,
        };
        // Decorrelated per-worker streams from one seed.
        let seed = crate::hashing::mix::splitmix64_mix(cfg.seed ^ ((w as u64 + 1) << 32));
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-{w}"))
            .spawn(move || worker_loop(tgt, &workload, pacer, duration, start, seed))
            .map_err(|e| format!("spawn worker {w}: {e}"))?;
        workers.push(handle);
    }
    let scrape_thread = scrape_admin.and_then(|admin| {
        let duration = cfg.duration;
        std::thread::Builder::new()
            .name("loadgen-scrape".into())
            .spawn(move || scrape_loop(admin, start, duration))
            .ok()
    });
    let churn_thread = match churn_admin {
        Some(admin) => {
            let buckets = cfg.cluster_buckets;
            Some(
                std::thread::Builder::new()
                    .name("loadgen-churn".into())
                    .spawn(move || churn::inject(admin, &plan, start, buckets))
                    .map_err(|e| format!("spawn churn injector: {e}"))?,
            )
        }
        None => None,
    };

    let mut merged = WorkerStats::new();
    for w in workers {
        let stats = w.join().map_err(|_| "a loadgen worker panicked".to_string())?;
        merged.merge(&stats);
    }
    let churn_events = match churn_thread {
        Some(t) => t.join().map_err(|_| "the churn injector panicked".to_string())?,
        None => Vec::new(),
    };
    let timeseries = match scrape_thread {
        Some(t) => t.join().unwrap_or_default(),
        None => Vec::new(),
    };
    let elapsed = start.elapsed();
    let node_loads = sample_node_loads(factory);

    Ok(RunReport {
        mode: cfg.mode.name().to_string(),
        workload: cfg.workload.name().to_string(),
        churn: cfg.churn.name().to_string(),
        threads,
        target_rate: match cfg.mode {
            Mode::Open { rate } => rate,
            Mode::Closed => 0.0,
        },
        elapsed,
        ops: merged.ops,
        errors: merged.errors,
        aborted_workers: merged.aborted_workers,
        acked_puts: merged.acked_puts,
        corrected: merged.corrected,
        naive: merged.naive,
        churn_events,
        node_loads,
        timeseries,
        availability: merged.per_second,
    })
}

/// Scrape cadence: 16 samples across the run, floored at 50 ms so short
/// smoke runs don't hammer the admin connection and capped at 1 s so
/// long runs still resolve churn events.
fn scrape_cadence(duration: Duration) -> Duration {
    (duration / 16).clamp(Duration::from_millis(50), Duration::from_secs(1))
}

/// The mid-run scraper: poll `MSAMPLE` + `STAGES` on a fixed cadence
/// until the schedule ends, stamping each sample with its offset from
/// run start. Best-effort — a failed call ends the scrape with whatever
/// was collected (the run itself is unaffected).
fn scrape_loop(
    mut admin: Box<dyn Target>,
    start: Instant,
    duration: Duration,
) -> Vec<report::TimeSample> {
    let cadence = scrape_cadence(duration);
    let mut out = Vec::new();
    while start.elapsed() < duration {
        std::thread::sleep(cadence);
        let offset_ms = start.elapsed().as_millis() as u64;
        let Ok(sample) = admin.call("MSAMPLE") else { break };
        let Ok(stages) = admin.call("STAGES") else { break };
        let Some(scalars) = report::parse_msample(&sample) else { break };
        let stages = report::parse_stages(&stages).unwrap_or_default();
        out.push(report::TimeSample { offset_ms, scalars, stages });
    }
    out
}

/// End-of-run per-node load sample via the `NODES` protocol command:
/// observed load vs configured weight for the report's balance section.
/// Best-effort — a target that cannot answer yields an empty sample, not
/// a failed run.
fn sample_node_loads(factory: &TargetFactory) -> Vec<NodeLoad> {
    let Ok(mut admin) = factory() else { return Vec::new() };
    let Ok(resp) = admin.call("NODES") else { return Vec::new() };
    let Some(rows) = resp.strip_prefix("NODES ") else { return Vec::new() };
    rows.split_whitespace().filter_map(NodeLoad::parse).collect()
}

fn worker_loop(
    mut tgt: Box<dyn Target>,
    workload: &Workload,
    mut pacer: Option<OpenLoopPacer>,
    duration: Duration,
    start: Instant,
    seed: u64,
) -> WorkerStats {
    let mut rng = Xoshiro256::new(seed);
    let mut stats = WorkerStats::new();
    loop {
        // The intended arrival: scheduled (open) or "now" (closed, where
        // corrected and naive latency coincide).
        let intended = match &mut pacer {
            Some(p) => match p.next_arrival(duration) {
                Some(t) => t,
                None => break,
            },
            None => {
                if start.elapsed() >= duration {
                    break;
                }
                Instant::now()
            }
        };
        let op = workload.next_op(&mut rng);
        let line = op.to_line();
        let sent = Instant::now();
        // Availability bucket: whole seconds since run start, stamped at
        // send time so a response delayed across a second boundary still
        // charges the second the request was offered in.
        let second = sent.duration_since(start).as_secs();
        match tgt.call(&line) {
            Ok(resp) => {
                let done = Instant::now();
                if resp.is_empty() || resp.starts_with("ERR") || resp.starts_with("BUSY") {
                    stats.errors += 1;
                    stats.record_second(second, false);
                    continue;
                }
                stats.ops += 1;
                stats.record_second(second, true);
                if op.is_put() && resp.starts_with("OK") {
                    stats.acked_puts += 1;
                }
                stats
                    .corrected
                    .record(crate::metrics::duration_to_ns(done.duration_since(intended)));
                stats.naive.record(crate::metrics::duration_to_ns(done.duration_since(sent)));
            }
            Err(_) => {
                // Transport failure: the connection is gone; stop this
                // worker rather than skewing the histograms with retries,
                // and flag the abort so the report can say the offered
                // load fell short.
                stats.errors += 1;
                stats.record_second(second, false);
                stats.aborted_workers = 1;
                break;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;
    use crate::coordinator::service::Service;

    fn inproc() -> (std::sync::Arc<Router>, TargetFactory) {
        let router = Router::new("memento", 8, 80, None).unwrap();
        let svc = Service::new(router.clone());
        (router, target::inproc_factory(svc))
    }

    #[test]
    fn closed_loop_run_counts_every_op() {
        let (_router, factory) = inproc();
        assert_eq!(preload(&factory, 200).unwrap(), 200);
        let cfg = LoadgenConfig {
            workload: Workload::uniform(200, 0.5),
            threads: 2,
            duration: Duration::from_millis(100),
            ..LoadgenConfig::default()
        };
        let rep = run(&cfg, &factory).unwrap();
        assert!(rep.ops > 100, "ops {}", rep.ops);
        assert_eq!(rep.errors, 0);
        assert_eq!(rep.aborted_workers, 0);
        assert_eq!(rep.ops, rep.corrected.count());
        assert_eq!(rep.ops, rep.naive.count());
        assert!(rep.acked_puts > 0);
        assert!(rep.throughput() > 0.0);
        // The end-of-run NODES sample feeds the balance section.
        assert_eq!(rep.node_loads.len(), 8, "{:?}", rep.node_loads);
        assert!(rep.node_loads.iter().all(|n| n.weight == 1));
        assert!(rep.node_loads.iter().map(|n| n.ops()).sum::<u64>() > 0);
        // Every operation lands in exactly one per-second availability
        // bucket, so the trajectory totals reconcile with the run totals.
        let (ok, err) = rep
            .availability
            .iter()
            .fold((0u64, 0u64), |(o, e), (ok, err)| (o + ok, e + err));
        assert_eq!(ok, rep.ops, "{:?}", rep.availability);
        assert_eq!(err, rep.errors);
        assert_eq!(rep.min_availability().unwrap().1, 1.0, "clean run");
    }

    #[test]
    fn weighted_cluster_load_follows_the_weights() {
        let router = Router::new("memento", 8, 160, None).unwrap();
        let heavy = router.with_view(|_a, m| m.node_at(0)).unwrap();
        router.set_weight(heavy, 8).unwrap();
        let svc = Service::new(router);
        let factory = target::inproc_factory(svc);
        let cfg = LoadgenConfig {
            workload: Workload::uniform(5_000, 0.5),
            threads: 2,
            duration: Duration::from_millis(250),
            ..LoadgenConfig::default()
        };
        let rep = run(&cfg, &factory).unwrap();
        let loads = &rep.node_loads;
        assert_eq!(loads.len(), 8);
        let total: u64 = loads.iter().map(|n| n.ops()).sum();
        let heavy_name = heavy.to_string();
        let heavy_row = loads.iter().find(|n| n.node == heavy_name).unwrap();
        assert_eq!(heavy_row.weight, 8);
        assert_eq!(heavy_row.buckets, 8);
        // Weight 8 of 15 → expect a bit over half the traffic; the gate
        // is generous (uniform keys, short run).
        let share = heavy_row.observed_share(total);
        assert!(
            (0.35..0.72).contains(&share),
            "weight-8/15 node served share {share:.3} of {total} ops"
        );
        assert!(rep.render().contains("weighted balance: max relative error="));
    }

    #[test]
    fn open_loop_hits_roughly_the_target_rate() {
        let (_router, factory) = inproc();
        let cfg = LoadgenConfig {
            mode: Mode::Open { rate: 4_000.0 },
            workload: Workload::uniform(100, 0.0),
            threads: 2,
            duration: Duration::from_millis(500),
            ..LoadgenConfig::default()
        };
        let rep = run(&cfg, &factory).unwrap();
        // 4000/s for 0.5 s = 2000 scheduled arrivals; an in-process target
        // never backlogs, so the whole schedule must be served.
        assert!((1_500..=2_100).contains(&rep.ops), "ops {}", rep.ops);
    }

    #[test]
    fn churn_scenario_changes_membership_mid_run() {
        let (router, factory) = inproc();
        let cfg = LoadgenConfig {
            workload: Workload::uniform(500, 0.3),
            threads: 2,
            duration: Duration::from_millis(300),
            churn: ChurnScenario::OneShot { kills: 2 },
            cluster_buckets: 8,
            ..LoadgenConfig::default()
        };
        let rep = run(&cfg, &factory).unwrap();
        assert_eq!(router.epoch(), 2, "both kills must land");
        assert_eq!(router.working(), 6);
        // The availability window is recorded per event.
        assert_eq!(rep.churn_events.len(), 2, "{:?}", rep.churn_events);
        for e in &rep.churn_events {
            assert_eq!(e.action, "kill", "{e:?}");
            assert!(e.admin_rtt_ns > 0, "{e:?}");
            assert!(e.epoch > 0, "{e:?}");
        }
        assert!(
            rep.churn_events[1].drain_ms.is_some(),
            "the last event has the full polling budget: {:?}",
            rep.churn_events
        );
        // The scraper ran alongside: a 300 ms run at the 50 ms floor
        // collects several samples, each with live scalar values.
        assert!(!rep.timeseries.is_empty(), "mid-run scrapes missing");
        let last = rep.timeseries.last().unwrap();
        assert!(
            last.scalar("memento_router_lookups_scalar").unwrap_or(0) > 0,
            "{last:?}"
        );
        assert!(rep.timeseries_table().is_some());
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(Mode::by_name("closed", 0.0).unwrap(), Mode::Closed);
        assert_eq!(Mode::by_name("open", 100.0).unwrap(), Mode::Open { rate: 100.0 });
        assert!(Mode::by_name("open", 0.0).is_err());
        assert!(Mode::by_name("open", f64::INFINITY).is_err());
        assert!(Mode::by_name("ajar", 1.0).is_err());
    }
}
