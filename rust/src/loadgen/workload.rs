//! Pluggable traffic workloads: which keys, and what to do with them.
//!
//! Key popularity is the axis the paper's balance claims live on —
//! consistent hashing balances key *slots*, not request *load* — so the
//! generator ships the three shapes a router meets in production:
//!
//! * **uniform** — every key equally likely (the paper's benchmark shape);
//! * **zipf(α)** — power-law popularity via [`crate::hashing::zipf`]
//!   (rank 0 is the hottest key);
//! * **hot** — a fixed hot set takes a fixed fraction of traffic (cache
//!   stampedes, celebrity objects).
//!
//! Orthogonally, `read_frac` splits every workload into a GET/PUT mix.

use crate::hashing::prng::Rng64;
use crate::hashing::zipf::{self, Zipf};
use std::sync::Arc;

/// Head-rank budget for [`ZipfTable`]: ranks `1..=65536` get an exact
/// precomputed CDF entry; everything deeper is sampled by the
/// rejection-inversion tail sampler. 64Ki `f64`s is 512 KiB once per
/// workload — shared by every worker thread via `Arc`, not per-thread.
const ZIPF_TABLE_RANKS: u64 = 65_536;

/// A Zipf(α) sampler tuned for the loadgen hot path: the head ranks —
/// where virtually all of the probability mass of a skewed law lives —
/// are drawn by binary search over a precomputed CDF (one `next_f64`
/// plus ~16 comparisons, no `ln`/`exp`), and only the rare deep-tail
/// draw falls back to the iterative rejection-inversion sampler.
///
/// The head CDF is exact (`Σ k^-α` summed term by term); the tail branch
/// weight uses the same `H(·)` integral the rejection sampler is built
/// on, so the head/tail split stays consistent with where the tail
/// sampler puts its mass.
#[derive(Debug)]
pub struct ZipfTable {
    /// `cdf[i]` = P(rank ≤ i) (0-based), normalized over the full
    /// keyspace (head + tail mass).
    cdf: Vec<f64>,
    /// Conditional sampler for ranks past the table. `None` when the
    /// table covers the whole keyspace.
    tail: Option<Zipf>,
    /// Total probability of landing in the head (== `cdf.last()`).
    head_mass: f64,
}

impl ZipfTable {
    /// Table over `0..n` keys (rank 0 hottest) with exponent `alpha`.
    pub fn new(n: u64, alpha: f64) -> Self {
        Self::with_head(n, alpha, ZIPF_TABLE_RANKS)
    }

    /// Table with an explicit head budget (tests shrink it to force the
    /// tail path; production uses [`ZipfTable::new`]).
    fn with_head(n: u64, alpha: f64, head_ranks: u64) -> Self {
        assert!(n >= 1, "zipf needs at least one element");
        assert!(alpha > 0.0, "zipf exponent must be positive");
        let head = n.min(head_ranks.max(1));
        let tail_mass = if head < n { zipf::tail_mass(head, n, alpha) } else { 0.0 };
        let mut cdf = Vec::with_capacity(head as usize);
        let mut acc = 0.0f64;
        for k in 1..=head {
            acc += (k as f64).powf(-alpha);
        }
        let total = acc + tail_mass;
        let mut run = 0.0f64;
        for k in 1..=head {
            run += (k as f64).powf(-alpha);
            cdf.push(run / total);
        }
        let tail = (head < n).then(|| Zipf::new_restricted(head + 1, n, alpha));
        let head_mass = acc / total;
        Self { cdf, tail, head_mass }
    }

    /// Analytic probability of the hottest key (rank 0) — what a perfect
    /// hot-key cache's hit rate on the top-1 key converges to.
    pub fn top1_mass(&self) -> f64 {
        self.cdf[0]
    }

    /// Analytic probability of the top `k` ranks together.
    pub fn head_mass(&self, k: usize) -> f64 {
        match k {
            0 => 0.0,
            k if k >= self.cdf.len() => self.head_mass,
            k => self.cdf[k - 1],
        }
    }

    /// Draw one sample (0-based rank; 0 is the most popular).
    pub fn sample<R: Rng64>(&self, rng: &mut R) -> u64 {
        let r = rng.next_f64();
        if r < self.head_mass {
            // First index whose cumulative mass exceeds the draw.
            return self.cdf.partition_point(|&c| c <= r) as u64;
        }
        match &self.tail {
            Some(t) => t.sample(rng),
            // r can tie head_mass on rounding even with no tail: clamp
            // to the deepest tabulated rank.
            None => self.cdf.len() as u64 - 1,
        }
    }
}

/// One generated operation, rendered to the service line protocol by
/// [`Op::to_line`]. Keys are decimal u64 tokens, which the service takes
/// verbatim (no edge digest), so placement is reproducible across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Read a key.
    Get(u64),
    /// Write a key (value is derived from the key).
    Put(u64),
}

impl Op {
    /// Render as a service protocol line.
    pub fn to_line(self) -> String {
        match self {
            Op::Get(k) => format!("GET {k}"),
            Op::Put(k) => format!("PUT {k} v{k}"),
        }
    }

    /// Whether this is a write.
    pub fn is_put(self) -> bool {
        matches!(self, Op::Put(_))
    }
}

/// How keys are drawn from the keyspace.
#[derive(Debug, Clone)]
enum KeyDist {
    Uniform,
    /// `Arc`-shared: every worker thread clones the `Workload`, and the
    /// 512 KiB CDF table is built once, not once per worker.
    Zipf(Arc<ZipfTable>),
    Hot {
        /// Fraction of traffic aimed at the hot set.
        hot_frac: f64,
        /// Size of the hot set (keys `0..hot_keys`).
        hot_keys: u64,
    },
}

/// A traffic shape: key distribution × read/write mix over a keyspace.
#[derive(Debug, Clone)]
pub struct Workload {
    dist: KeyDist,
    keyspace: u64,
    read_frac: f64,
}

/// Clamp a probability to `[0, 1]`, mapping NaN to 0.
fn clamp01(x: f64) -> f64 {
    if x.is_nan() {
        0.0
    } else {
        x.clamp(0.0, 1.0)
    }
}

impl Workload {
    /// Uniform keys over `0..keyspace`.
    pub fn uniform(keyspace: u64, read_frac: f64) -> Self {
        Self { dist: KeyDist::Uniform, keyspace: keyspace.max(1), read_frac: clamp01(read_frac) }
    }

    /// Zipf(α) keys over `0..keyspace` (key 0 is the hottest).
    pub fn zipf(keyspace: u64, alpha: f64, read_frac: f64) -> Self {
        let n = keyspace.max(1);
        Self {
            dist: KeyDist::Zipf(Arc::new(ZipfTable::new(n, alpha))),
            keyspace: n,
            read_frac: clamp01(read_frac),
        }
    }

    /// A hot set of `hot_keys` keys receiving `hot_frac` of all traffic;
    /// the rest is uniform over the full keyspace.
    pub fn hot(keyspace: u64, hot_frac: f64, hot_keys: u64, read_frac: f64) -> Self {
        let n = keyspace.max(1);
        Self {
            dist: KeyDist::Hot {
                hot_frac: clamp01(hot_frac),
                hot_keys: hot_keys.clamp(1, n),
            },
            keyspace: n,
            read_frac: clamp01(read_frac),
        }
    }

    /// Build by CLI name: `uniform`, `zipf(alpha)`, or
    /// `hot(hot_frac, hot_keys)` — the parameters the named shape doesn't
    /// use are ignored.
    pub fn by_name(
        name: &str,
        keyspace: u64,
        alpha: f64,
        hot_frac: f64,
        hot_keys: u64,
        read_frac: f64,
    ) -> Result<Self, String> {
        match name {
            "uniform" => Ok(Self::uniform(keyspace, read_frac)),
            "zipf" => {
                if !alpha.is_finite() || alpha <= 0.0 {
                    return Err(format!("zipf exponent must be a positive number, got {alpha}"));
                }
                Ok(Self::zipf(keyspace, alpha, read_frac))
            }
            "hot" => Ok(Self::hot(keyspace, hot_frac, hot_keys, read_frac)),
            other => Err(format!("unknown workload '{other}' (uniform|zipf|hot)")),
        }
    }

    /// The workload's display name.
    pub fn name(&self) -> &'static str {
        match self.dist {
            KeyDist::Uniform => "uniform",
            KeyDist::Zipf(_) => "zipf",
            KeyDist::Hot { .. } => "hot",
        }
    }

    /// Keyspace size.
    pub fn keyspace(&self) -> u64 {
        self.keyspace
    }

    /// Draw the next key.
    pub fn next_key<R: Rng64>(&self, rng: &mut R) -> u64 {
        match &self.dist {
            KeyDist::Uniform => rng.next_below(self.keyspace),
            KeyDist::Zipf(z) => z.sample(rng),
            KeyDist::Hot { hot_frac, hot_keys } => {
                if rng.next_bool(*hot_frac) {
                    rng.next_below(*hot_keys)
                } else {
                    rng.next_below(self.keyspace)
                }
            }
        }
    }

    /// Draw the next operation (GET with probability `read_frac`).
    pub fn next_op<R: Rng64>(&self, rng: &mut R) -> Op {
        let key = self.next_key(rng);
        if rng.next_bool(self.read_frac) {
            Op::Get(key)
        } else {
            Op::Put(key)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::prng::Xoshiro256;

    #[test]
    fn ops_render_to_protocol_lines() {
        assert_eq!(Op::Get(7).to_line(), "GET 7");
        assert_eq!(Op::Put(9).to_line(), "PUT 9 v9");
        assert!(Op::Put(1).is_put());
        assert!(!Op::Get(1).is_put());
    }

    #[test]
    fn read_frac_controls_the_mix() {
        let w = Workload::uniform(1000, 0.75);
        let mut rng = Xoshiro256::new(3);
        let reads =
            (0..20_000).filter(|_| matches!(w.next_op(&mut rng), Op::Get(_))).count();
        let frac = reads as f64 / 20_000.0;
        assert!((0.70..0.80).contains(&frac), "read fraction {frac}");
    }

    #[test]
    fn zipf_workload_skews_to_low_ranks() {
        let w = Workload::zipf(10_000, 1.2, 1.0);
        let mut rng = Xoshiro256::new(5);
        let mut head = 0u32;
        for _ in 0..20_000 {
            if w.next_key(&mut rng) < 10 {
                head += 1;
            }
        }
        // The top 10 of 10k keys must take far more than their 0.1% share.
        assert!(head > 2_000, "head hits {head}");
    }

    #[test]
    fn hot_workload_concentrates_on_the_hot_set() {
        let w = Workload::hot(100_000, 0.9, 16, 0.5);
        let mut rng = Xoshiro256::new(9);
        let mut hot = 0u32;
        for _ in 0..20_000 {
            if w.next_key(&mut rng) < 16 {
                hot += 1;
            }
        }
        let frac = hot as f64 / 20_000.0;
        assert!((0.85..0.95).contains(&frac), "hot fraction {frac}");
    }

    #[test]
    fn zipf_table_top1_frequency_matches_the_analytic_mass() {
        // The guarantee the hot-cache benchmarks lean on: the sampled
        // top-1 frequency tracks the analytic Zipf mass, so measured hit
        // rates can be compared against `top1_mass`/`head_mass` directly.
        let t = ZipfTable::new(10_000, 1.2);
        let mut rng = Xoshiro256::new(7);
        let trials = 200_000u32;
        let mut top1 = 0u32;
        for _ in 0..trials {
            if t.sample(&mut rng) == 0 {
                top1 += 1;
            }
        }
        let empirical = top1 as f64 / trials as f64;
        let analytic = t.top1_mass();
        let rel = ((empirical - analytic) / analytic).abs();
        assert!(
            rel < 0.05,
            "top-1 frequency {empirical:.4} vs analytic {analytic:.4} (rel err {rel:.4})"
        );
        assert!(analytic > 0.15, "zipf(1.2) top-1 mass should be substantial: {analytic}");
    }

    #[test]
    fn zipf_table_head_and_tail_masses_are_consistent() {
        let t = ZipfTable::with_head(1_000, 1.0, 16);
        // Head/tail split: the full head mass plus nothing is below 1,
        // head_mass(k) is monotone, and sampling crosses the boundary.
        assert!(t.head_mass(16) < 1.0, "a 1000-key space has tail mass");
        assert!(t.head_mass(1) < t.head_mass(8));
        assert_eq!(t.head_mass(0), 0.0);
        let mut rng = Xoshiro256::new(13);
        let trials = 100_000u32;
        let mut in_head = 0u32;
        for _ in 0..trials {
            let k = t.sample(&mut rng);
            assert!(k < 1_000, "sample {k} escaped the keyspace");
            if k < 16 {
                in_head += 1;
            }
        }
        let empirical = in_head as f64 / trials as f64;
        let analytic = t.head_mass(16);
        let rel = ((empirical - analytic) / analytic).abs();
        assert!(
            rel < 0.05,
            "head frequency {empirical:.4} vs analytic {analytic:.4} (rel err {rel:.4})"
        );
    }

    #[test]
    fn keys_stay_in_the_keyspace() {
        let mut rng = Xoshiro256::new(1);
        for w in [
            Workload::uniform(100, 0.5),
            Workload::zipf(100, 0.8, 0.5),
            Workload::hot(100, 0.5, 10, 0.5),
        ] {
            for _ in 0..5_000 {
                assert!(w.next_key(&mut rng) < 100);
            }
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(Workload::by_name("uniform", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("zipf", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("zipf", 10, 0.0, 0.9, 4, 0.5).is_err());
        assert!(Workload::by_name("hot", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("pareto", 10, 1.0, 0.9, 4, 0.5).is_err());
    }
}
