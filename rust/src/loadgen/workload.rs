//! Pluggable traffic workloads: which keys, and what to do with them.
//!
//! Key popularity is the axis the paper's balance claims live on —
//! consistent hashing balances key *slots*, not request *load* — so the
//! generator ships the three shapes a router meets in production:
//!
//! * **uniform** — every key equally likely (the paper's benchmark shape);
//! * **zipf(α)** — power-law popularity via [`crate::hashing::zipf`]
//!   (rank 0 is the hottest key);
//! * **hot** — a fixed hot set takes a fixed fraction of traffic (cache
//!   stampedes, celebrity objects).
//!
//! Orthogonally, `read_frac` splits every workload into a GET/PUT mix.

use crate::hashing::prng::Rng64;
use crate::hashing::zipf::Zipf;

/// One generated operation, rendered to the service line protocol by
/// [`Op::to_line`]. Keys are decimal u64 tokens, which the service takes
/// verbatim (no edge digest), so placement is reproducible across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Read a key.
    Get(u64),
    /// Write a key (value is derived from the key).
    Put(u64),
}

impl Op {
    /// Render as a service protocol line.
    pub fn to_line(self) -> String {
        match self {
            Op::Get(k) => format!("GET {k}"),
            Op::Put(k) => format!("PUT {k} v{k}"),
        }
    }

    /// Whether this is a write.
    pub fn is_put(self) -> bool {
        matches!(self, Op::Put(_))
    }
}

/// How keys are drawn from the keyspace.
#[derive(Debug, Clone)]
enum KeyDist {
    Uniform,
    Zipf(Zipf),
    Hot {
        /// Fraction of traffic aimed at the hot set.
        hot_frac: f64,
        /// Size of the hot set (keys `0..hot_keys`).
        hot_keys: u64,
    },
}

/// A traffic shape: key distribution × read/write mix over a keyspace.
#[derive(Debug, Clone)]
pub struct Workload {
    dist: KeyDist,
    keyspace: u64,
    read_frac: f64,
}

/// Clamp a probability to `[0, 1]`, mapping NaN to 0.
fn clamp01(x: f64) -> f64 {
    if x.is_nan() {
        0.0
    } else {
        x.clamp(0.0, 1.0)
    }
}

impl Workload {
    /// Uniform keys over `0..keyspace`.
    pub fn uniform(keyspace: u64, read_frac: f64) -> Self {
        Self { dist: KeyDist::Uniform, keyspace: keyspace.max(1), read_frac: clamp01(read_frac) }
    }

    /// Zipf(α) keys over `0..keyspace` (key 0 is the hottest).
    pub fn zipf(keyspace: u64, alpha: f64, read_frac: f64) -> Self {
        let n = keyspace.max(1);
        Self {
            dist: KeyDist::Zipf(Zipf::new(n, alpha)),
            keyspace: n,
            read_frac: clamp01(read_frac),
        }
    }

    /// A hot set of `hot_keys` keys receiving `hot_frac` of all traffic;
    /// the rest is uniform over the full keyspace.
    pub fn hot(keyspace: u64, hot_frac: f64, hot_keys: u64, read_frac: f64) -> Self {
        let n = keyspace.max(1);
        Self {
            dist: KeyDist::Hot {
                hot_frac: clamp01(hot_frac),
                hot_keys: hot_keys.clamp(1, n),
            },
            keyspace: n,
            read_frac: clamp01(read_frac),
        }
    }

    /// Build by CLI name: `uniform`, `zipf(alpha)`, or
    /// `hot(hot_frac, hot_keys)` — the parameters the named shape doesn't
    /// use are ignored.
    pub fn by_name(
        name: &str,
        keyspace: u64,
        alpha: f64,
        hot_frac: f64,
        hot_keys: u64,
        read_frac: f64,
    ) -> Result<Self, String> {
        match name {
            "uniform" => Ok(Self::uniform(keyspace, read_frac)),
            "zipf" => {
                if !alpha.is_finite() || alpha <= 0.0 {
                    return Err(format!("zipf exponent must be a positive number, got {alpha}"));
                }
                Ok(Self::zipf(keyspace, alpha, read_frac))
            }
            "hot" => Ok(Self::hot(keyspace, hot_frac, hot_keys, read_frac)),
            other => Err(format!("unknown workload '{other}' (uniform|zipf|hot)")),
        }
    }

    /// The workload's display name.
    pub fn name(&self) -> &'static str {
        match self.dist {
            KeyDist::Uniform => "uniform",
            KeyDist::Zipf(_) => "zipf",
            KeyDist::Hot { .. } => "hot",
        }
    }

    /// Keyspace size.
    pub fn keyspace(&self) -> u64 {
        self.keyspace
    }

    /// Draw the next key.
    pub fn next_key<R: Rng64>(&self, rng: &mut R) -> u64 {
        match &self.dist {
            KeyDist::Uniform => rng.next_below(self.keyspace),
            KeyDist::Zipf(z) => z.sample(rng),
            KeyDist::Hot { hot_frac, hot_keys } => {
                if rng.next_bool(*hot_frac) {
                    rng.next_below(*hot_keys)
                } else {
                    rng.next_below(self.keyspace)
                }
            }
        }
    }

    /// Draw the next operation (GET with probability `read_frac`).
    pub fn next_op<R: Rng64>(&self, rng: &mut R) -> Op {
        let key = self.next_key(rng);
        if rng.next_bool(self.read_frac) {
            Op::Get(key)
        } else {
            Op::Put(key)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::prng::Xoshiro256;

    #[test]
    fn ops_render_to_protocol_lines() {
        assert_eq!(Op::Get(7).to_line(), "GET 7");
        assert_eq!(Op::Put(9).to_line(), "PUT 9 v9");
        assert!(Op::Put(1).is_put());
        assert!(!Op::Get(1).is_put());
    }

    #[test]
    fn read_frac_controls_the_mix() {
        let w = Workload::uniform(1000, 0.75);
        let mut rng = Xoshiro256::new(3);
        let reads =
            (0..20_000).filter(|_| matches!(w.next_op(&mut rng), Op::Get(_))).count();
        let frac = reads as f64 / 20_000.0;
        assert!((0.70..0.80).contains(&frac), "read fraction {frac}");
    }

    #[test]
    fn zipf_workload_skews_to_low_ranks() {
        let w = Workload::zipf(10_000, 1.2, 1.0);
        let mut rng = Xoshiro256::new(5);
        let mut head = 0u32;
        for _ in 0..20_000 {
            if w.next_key(&mut rng) < 10 {
                head += 1;
            }
        }
        // The top 10 of 10k keys must take far more than their 0.1% share.
        assert!(head > 2_000, "head hits {head}");
    }

    #[test]
    fn hot_workload_concentrates_on_the_hot_set() {
        let w = Workload::hot(100_000, 0.9, 16, 0.5);
        let mut rng = Xoshiro256::new(9);
        let mut hot = 0u32;
        for _ in 0..20_000 {
            if w.next_key(&mut rng) < 16 {
                hot += 1;
            }
        }
        let frac = hot as f64 / 20_000.0;
        assert!((0.85..0.95).contains(&frac), "hot fraction {frac}");
    }

    #[test]
    fn keys_stay_in_the_keyspace() {
        let mut rng = Xoshiro256::new(1);
        for w in [
            Workload::uniform(100, 0.5),
            Workload::zipf(100, 0.8, 0.5),
            Workload::hot(100, 0.5, 10, 0.5),
        ] {
            for _ in 0..5_000 {
                assert!(w.next_key(&mut rng) < 100);
            }
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(Workload::by_name("uniform", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("zipf", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("zipf", 10, 0.0, 0.9, 4, 0.5).is_err());
        assert!(Workload::by_name("hot", 10, 1.0, 0.9, 4, 0.5).is_ok());
        assert!(Workload::by_name("pareto", 10, 1.0, 0.9, 4, 0.5).is_err());
    }
}
