//! Open-loop pacing with coordinated-omission correction.
//!
//! A **closed-loop** generator (N workers, back-to-back requests) silently
//! stops offering load the moment the service stalls: the stalled request
//! blocks its worker, no new requests arrive, and the latency histogram
//! never sees the requests that *would have* arrived — Gil Tene's
//! "coordinated omission". An **open-loop** generator fixes the arrival
//! schedule in advance, independent of the service: each request has an
//! *intended* arrival time, and its latency is measured from that intended
//! time, not from when the (possibly backlogged) worker actually got to
//! send it. A stall therefore charges every queued arrival with its full
//! wait, exactly what a real user behind the stall would experience.
//!
//! [`OpenLoopPacer`] produces that schedule: arrivals every `interval_ns`
//! from a fixed start. When ahead of schedule it sleeps; when behind it
//! returns immediately (never skipping an arrival) so the backlog drains
//! at full speed while latencies stay anchored to the schedule.

use std::time::{Duration, Instant};

/// Fixed-rate arrival schedule for one worker.
#[derive(Debug)]
pub struct OpenLoopPacer {
    start: Instant,
    interval_ns: u64,
    next_ns: u64,
}

impl OpenLoopPacer {
    /// A pacer issuing one arrival every `interval_ns` nanoseconds,
    /// anchored at `start`.
    pub fn new(start: Instant, interval_ns: u64) -> Self {
        Self { start, interval_ns: interval_ns.max(1), next_ns: 0 }
    }

    /// A pacer for `rate` arrivals per second.
    pub fn with_rate(start: Instant, rate: f64) -> Self {
        assert!(rate > 0.0, "open-loop rate must be positive");
        Self::new(start, (1e9 / rate) as u64)
    }

    /// Shift the whole schedule by `offset_ns`. With N same-rate workers,
    /// phase worker `w` by `w * interval / N` so the combined stream is
    /// uniform instead of N-request bursts every interval — bursts queue
    /// behind each other and would charge self-induced waiting to the
    /// service's tail.
    pub fn with_phase(mut self, offset_ns: u64) -> Self {
        self.next_ns = offset_ns;
        self
    }

    /// Block until the next intended arrival and return its scheduled
    /// time, or `None` once the schedule passes `duration`. When the
    /// caller is behind schedule this returns immediately — the arrival is
    /// late, not dropped, and latency measured from the returned instant
    /// includes the queueing delay (the coordinated-omission correction).
    pub fn next_arrival(&mut self, duration: Duration) -> Option<Instant> {
        if u128::from(self.next_ns) >= duration.as_nanos() {
            return None;
        }
        let intended = self.start + Duration::from_nanos(self.next_ns);
        self.next_ns += self.interval_ns;
        let now = Instant::now();
        if intended > now {
            std::thread::sleep(intended - now);
        }
        Some(intended)
    }

    /// Nanoseconds between scheduled arrivals.
    pub fn interval_ns(&self) -> u64 {
        self.interval_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_fixed_rate_and_bounded() {
        let start = Instant::now();
        let mut p = OpenLoopPacer::new(start, 1_000_000); // 1 ms
        let mut arrivals = Vec::new();
        while let Some(t) = p.next_arrival(Duration::from_millis(20)) {
            arrivals.push(t);
        }
        assert_eq!(arrivals.len(), 20);
        for (i, t) in arrivals.iter().enumerate() {
            assert_eq!(
                t.duration_since(start).as_nanos() as u64 / 1_000_000,
                i as u64,
                "arrival {i} off schedule"
            );
        }
    }

    #[test]
    fn late_callers_get_past_arrivals_immediately() {
        let start = Instant::now();
        let mut p = OpenLoopPacer::new(start, 1_000_000);
        // Simulate a 10 ms service stall before the first poll.
        std::thread::sleep(Duration::from_millis(10));
        let t0 = Instant::now();
        let mut got = 0;
        // The ~10 backlogged arrivals must be handed out without sleeping.
        for _ in 0..8 {
            let intended = p.next_arrival(Duration::from_millis(50)).unwrap();
            assert!(intended <= Instant::now(), "backlogged arrival is in the past");
            got += 1;
        }
        assert_eq!(got, 8);
        assert!(
            t0.elapsed() < Duration::from_millis(5),
            "backlog drain must not sleep, took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn rate_maps_to_interval() {
        let p = OpenLoopPacer::with_rate(Instant::now(), 10_000.0);
        assert_eq!(p.interval_ns(), 100_000);
    }

    #[test]
    fn phased_pacers_interleave_instead_of_bursting() {
        let start = Instant::now();
        let dur = Duration::from_millis(8);
        let mut a = OpenLoopPacer::new(start, 2_000_000);
        let mut b = OpenLoopPacer::new(start, 2_000_000).with_phase(1_000_000);
        let mut arrivals = Vec::new();
        while let Some(t) = a.next_arrival(dur) {
            arrivals.push(t.duration_since(start).as_nanos() as u64);
        }
        while let Some(t) = b.next_arrival(dur) {
            arrivals.push(t.duration_since(start).as_nanos() as u64);
        }
        arrivals.sort_unstable();
        // Combined stream: one arrival every 1 ms, no duplicates.
        assert_eq!(arrivals, (0..8).map(|i| i * 1_000_000).collect::<Vec<_>>());
    }
}
