//! Run reporting: per-thread stats merged into one report, rendered for
//! humans and emitted as CSV (via [`crate::benchkit::report`]) and JSON so
//! results land in the benchmark trajectory next to the figure CSVs.

use super::churn::ChurnEvent;
use crate::benchkit::{self, report::Table};
use crate::metrics::Histogram;
use std::time::Duration;

/// One node's end-of-run load sample, parsed from the service's `NODES`
/// reply (`name:weight:buckets:records:gets:puts`). The interesting
/// figure for weighted clusters is observed share vs configured weight
/// share — see [`NodeLoad::observed_share`] / [`RunReport::node_table`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeLoad {
    /// Node display name.
    pub node: String,
    /// Configured weight.
    pub weight: u32,
    /// Bound bucket count at sample time.
    pub buckets: u32,
    /// Records held at sample time.
    pub records: u64,
    /// GETs served.
    pub gets: u64,
    /// PUTs served.
    pub puts: u64,
}

impl NodeLoad {
    /// Parse one `name:weight:buckets:records:gets:puts` token.
    pub fn parse(token: &str) -> Option<NodeLoad> {
        let mut f = token.split(':');
        let node = f.next()?.to_string();
        let mut num = || f.next()?.parse::<u64>().ok();
        let (weight, buckets, records, gets, puts) = (num()?, num()?, num()?, num()?, num()?);
        if f.next().is_some() {
            return None;
        }
        Some(NodeLoad {
            node,
            weight: weight as u32,
            buckets: buckets as u32,
            records,
            gets,
            puts,
        })
    }

    /// Operations this node served (GET + PUT).
    pub fn ops(&self) -> u64 {
        self.gets + self.puts
    }

    /// This node's share of `total_ops`.
    pub fn observed_share(&self, total_ops: u64) -> f64 {
        self.ops() as f64 / total_ops.max(1) as f64
    }
}

/// One node's computed balance figures: observed traffic share vs the
/// weight share it should carry. Produced by `RunReport::balance_rows`.
#[derive(Debug, Clone, Copy)]
struct BalanceRow {
    /// The node's share of all sampled operations.
    observed: f64,
    /// `weight / Σweights` — the share the configuration asks for.
    want: f64,
}

impl BalanceRow {
    /// Signed absolute error (`observed - want`).
    fn err(&self) -> f64 {
        self.observed - self.want
    }

    /// Relative error `|observed - want| / want`, guarded against a
    /// zero weight share.
    fn rel_err(&self) -> f64 {
        self.err().abs() / self.want.max(f64::EPSILON)
    }
}

/// What one worker thread measured. Merged across threads at the end of a
/// run via [`Histogram::merge`].
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Successfully answered operations.
    pub ops: u64,
    /// Errored operations (`ERR …`, empty responses, transport failures).
    pub errors: u64,
    /// Workers that lost their transport mid-run and abandoned the rest
    /// of their schedule (1 for a single worker's stats; summed on merge).
    pub aborted_workers: u64,
    /// PUTs acknowledged with `OK` (the writes a durability check must
    /// find again).
    pub acked_puts: u64,
    /// Latency measured from the *intended* arrival time (coordinated-
    /// omission-corrected; equals `naive` in closed-loop mode).
    pub corrected: Histogram,
    /// Latency measured from the actual send time.
    pub naive: Histogram,
}

impl WorkerStats {
    /// Empty stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold another worker's stats into this one.
    pub fn merge(&mut self, other: &WorkerStats) {
        self.ops += other.ops;
        self.errors += other.errors;
        self.aborted_workers += other.aborted_workers;
        self.acked_puts += other.acked_puts;
        self.corrected.merge(&other.corrected);
        self.naive.merge(&other.naive);
    }
}

/// The merged result of one loadgen run.
#[derive(Debug)]
pub struct RunReport {
    /// Generator mode (`closed` / `open`).
    pub mode: String,
    /// Workload name.
    pub workload: String,
    /// Churn scenario name.
    pub churn: String,
    /// Worker thread count.
    pub threads: usize,
    /// Open-loop target rate in ops/s (0 for closed-loop).
    pub target_rate: f64,
    /// Wall-clock run length (includes backlog drain past the schedule).
    pub elapsed: Duration,
    /// Successfully answered operations across all threads.
    pub ops: u64,
    /// Errored operations across all threads.
    pub errors: u64,
    /// Workers that lost their transport and abandoned their schedule —
    /// nonzero means the offered load fell short of the configured rate.
    pub aborted_workers: u64,
    /// PUTs acknowledged with `OK`.
    pub acked_puts: u64,
    /// Merged CO-corrected latency histogram (nanoseconds).
    pub corrected: Histogram,
    /// Merged naive (send-to-response) latency histogram (nanoseconds).
    pub naive: Histogram,
    /// Structured churn events with the measured availability window
    /// (epoch, admin rtt, drain time) and the human log line — see
    /// [`ChurnEvent`].
    pub churn_events: Vec<ChurnEvent>,
    /// End-of-run per-node load (from the `NODES` protocol command):
    /// observed load vs configured weight, so weighted runs show balance
    /// error end to end. Empty when the target did not answer `NODES`.
    pub node_loads: Vec<NodeLoad>,
}

impl RunReport {
    /// Achieved throughput in ops/s.
    pub fn throughput(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.ops as f64 / secs
        } else {
            0.0
        }
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let q = |h: &Histogram, p: f64| benchkit::fmt_ns(h.quantile(p) as f64);
        let mut out = String::new();
        out.push_str("== loadgen report ==\n");
        out.push_str(&format!(
            "mode={} workload={} churn={} threads={}",
            self.mode, self.workload, self.churn, self.threads
        ));
        if self.target_rate > 0.0 {
            out.push_str(&format!(" rate={:.0}/s", self.target_rate));
        }
        out.push('\n');
        out.push_str(&format!(
            "elapsed={:.2?} ops={} errors={} acked_puts={} throughput={:.0} ops/s\n",
            self.elapsed,
            self.ops,
            self.errors,
            self.acked_puts,
            self.throughput()
        ));
        if self.aborted_workers > 0 {
            out.push_str(&format!(
                "WARNING: {} of {} workers lost their connection and abandoned \
                 their schedule — offered load fell short of the target\n",
                self.aborted_workers, self.threads
            ));
        }
        out.push_str(&format!(
            "latency (CO-corrected): p50={} p90={} p99={} p999={} max={}\n",
            q(&self.corrected, 0.5),
            q(&self.corrected, 0.9),
            q(&self.corrected, 0.99),
            q(&self.corrected, 0.999),
            benchkit::fmt_ns(self.corrected.max() as f64)
        ));
        out.push_str(&format!(
            "latency (naive):        p50={} p90={} p99={} p999={} max={}\n",
            q(&self.naive, 0.5),
            q(&self.naive, 0.9),
            q(&self.naive, 0.99),
            q(&self.naive, 0.999),
            benchkit::fmt_ns(self.naive.max() as f64)
        ));
        if !self.node_loads.is_empty() {
            out.push_str("per-node load (observed share vs weight share):\n");
            let mut err_max = 0.0f64;
            for (n, b) in self.balance_rows() {
                err_max = err_max.max(b.rel_err());
                out.push_str(&format!(
                    "  {:<10} w={:<2} buckets={:<2} records={:<7} ops={:<8} \
                     share={:.3} want={:.3} err={:+.3}\n",
                    n.node,
                    n.weight,
                    n.buckets,
                    n.records,
                    n.ops(),
                    b.observed,
                    b.want,
                    b.err()
                ));
            }
            out.push_str(&format!("weighted balance: max relative error={err_max:.3}\n"));
        }
        if !self.churn_events.is_empty() {
            out.push_str("churn events:\n");
            for e in &self.churn_events {
                out.push_str(&format!("  {}\n", e.line));
            }
            let rtts: Vec<u64> =
                self.churn_events.iter().map(|e| e.admin_rtt_ns).filter(|&n| n > 0).collect();
            let drains: Vec<f64> = self.churn_events.iter().filter_map(|e| e.drain_ms).collect();
            if let Some(&max_rtt) = rtts.iter().max() {
                out.push_str(&format!(
                    "availability: admin_rtt max={} over {} events",
                    benchkit::fmt_ns(max_rtt as f64),
                    rtts.len()
                ));
                if !drains.is_empty() {
                    let max_drain = drains.iter().copied().fold(f64::MIN, f64::max);
                    out.push_str(&format!(
                        ", drain max={max_drain:.1}ms ({} measured)",
                        drains.len()
                    ));
                }
                out.push('\n');
            }
        }
        out
    }

    /// Per-event availability table for the `results/` CSV trajectory
    /// (`None` when the run had no churn). Unmeasured drains emit -1.
    pub fn events_table(&self) -> Option<Table> {
        if self.churn_events.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "loadgen_churn_events",
            &["offset_ms", "action", "epoch", "admin_rtt_us", "drain_ms"],
        );
        for e in &self.churn_events {
            t.push_row(vec![
                e.offset_ms.to_string(),
                e.action.to_string(),
                e.epoch.to_string(),
                format!("{:.1}", e.admin_rtt_ns as f64 / 1e3),
                e.drain_ms.map_or("-1".to_string(), |d| format!("{d:.3}")),
            ]);
        }
        Some(t)
    }

    /// Per-node balance figures (observed share vs weight share), the
    /// single source both [`RunReport::render`] and
    /// [`RunReport::node_table`] consume so the definition cannot drift
    /// between the human and CSV views.
    fn balance_rows(&self) -> Vec<(&NodeLoad, BalanceRow)> {
        let total_ops: u64 = self.node_loads.iter().map(|n| n.ops()).sum();
        let total_weight: u64 = self.node_loads.iter().map(|n| u64::from(n.weight)).sum();
        self.node_loads
            .iter()
            .map(|n| {
                let observed = n.observed_share(total_ops);
                let want = f64::from(n.weight) / total_weight.max(1) as f64;
                (n, BalanceRow { observed, want })
            })
            .collect()
    }

    /// Per-node observed-load vs configured-weight table for the
    /// `results/` CSV trajectory (`None` when the run collected no node
    /// loads).
    pub fn node_table(&self) -> Option<Table> {
        if self.node_loads.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "loadgen_nodes",
            &[
                "node", "weight", "buckets", "records", "gets", "puts", "observed_share",
                "weight_share", "balance_err",
            ],
        );
        for (n, b) in self.balance_rows() {
            t.push_row(vec![
                n.node.clone(),
                n.weight.to_string(),
                n.buckets.to_string(),
                n.records.to_string(),
                n.gets.to_string(),
                n.puts.to_string(),
                format!("{:.4}", b.observed),
                format!("{:.4}", b.want),
                format!("{:+.4}", b.err()),
            ]);
        }
        Some(t)
    }

    /// One-row table for the CSV trajectory under `results/`.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "loadgen",
            &[
                "mode", "workload", "churn", "threads", "rate", "elapsed_s", "ops", "errors",
                "throughput", "p50_ns", "p90_ns", "p99_ns", "p999_ns", "max_ns", "naive_p99_ns",
            ],
        );
        t.push_row(vec![
            self.mode.clone(),
            self.workload.clone(),
            self.churn.clone(),
            self.threads.to_string(),
            format!("{:.0}", self.target_rate),
            format!("{:.3}", self.elapsed.as_secs_f64()),
            self.ops.to_string(),
            self.errors.to_string(),
            format!("{:.0}", self.throughput()),
            self.corrected.quantile(0.5).to_string(),
            self.corrected.quantile(0.9).to_string(),
            self.corrected.quantile(0.99).to_string(),
            self.corrected.quantile(0.999).to_string(),
            self.corrected.max().to_string(),
            self.naive.quantile(0.99).to_string(),
        ]);
        t
    }

    /// Serialize as a JSON object (hand-rolled; serde is not in the
    /// offline crate set).
    pub fn to_json(&self) -> String {
        let hist = |h: &Histogram| {
            format!(
                "{{\"n\": {}, \"mean_ns\": {:.1}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \
                 \"p999\": {}, \"max\": {}}}",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.9),
                h.quantile(0.99),
                h.quantile(0.999),
                h.max()
            )
        };
        let events: Vec<String> = self
            .churn_events
            .iter()
            .map(|e| {
                format!(
                    "{{\"offset_ms\": {}, \"action\": \"{}\", \"epoch\": {}, \
                     \"admin_rtt_ns\": {}, \"drain_ms\": {}, \"line\": \"{}\"}}",
                    e.offset_ms,
                    e.action,
                    e.epoch,
                    e.admin_rtt_ns,
                    e.drain_ms.map_or("null".to_string(), |d| format!("{d:.3}")),
                    json_escape(&e.line)
                )
            })
            .collect();
        format!(
            "{{\n  \"mode\": \"{}\",\n  \"workload\": \"{}\",\n  \"churn\": \"{}\",\n  \
             \"threads\": {},\n  \"target_rate\": {:.1},\n  \"elapsed_s\": {:.3},\n  \
             \"ops\": {},\n  \"errors\": {},\n  \"aborted_workers\": {},\n  \
             \"acked_puts\": {},\n  \
             \"throughput\": {:.1},\n  \"latency_ns\": {},\n  \"naive_latency_ns\": {},\n  \
             \"churn_events\": [{}]\n}}\n",
            json_escape(&self.mode),
            json_escape(&self.workload),
            json_escape(&self.churn),
            self.threads,
            self.target_rate,
            self.elapsed.as_secs_f64(),
            self.ops,
            self.errors,
            self.aborted_workers,
            self.acked_puts,
            self.throughput(),
            hist(&self.corrected),
            hist(&self.naive),
            events.join(", ")
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> RunReport {
        let mut corrected = Histogram::new();
        let mut naive = Histogram::new();
        for i in 1..=1000u64 {
            corrected.record(i * 1000);
            naive.record(i * 500);
        }
        RunReport {
            mode: "open".into(),
            workload: "zipf".into(),
            churn: "incremental".into(),
            threads: 4,
            target_rate: 10_000.0,
            elapsed: Duration::from_secs(2),
            ops: 1000,
            errors: 0,
            aborted_workers: 0,
            acked_puts: 300,
            corrected,
            naive,
            churn_events: vec![ChurnEvent {
                offset_ms: 500,
                action: "kill",
                epoch: 1,
                admin_rtt_ns: 84_000,
                drain_ms: Some(3.2),
                line: "[500ms] KILL 3 -> KILLED node-3 EPOCH 1 SOURCES 1".into(),
            }],
            node_loads: vec![
                NodeLoad {
                    node: "node-0".into(),
                    weight: 3,
                    buckets: 3,
                    records: 600,
                    gets: 450,
                    puts: 150,
                },
                NodeLoad {
                    node: "node-1".into(),
                    weight: 1,
                    buckets: 1,
                    records: 200,
                    gets: 150,
                    puts: 50,
                },
            ],
        }
    }

    #[test]
    fn worker_stats_merge_accumulates() {
        let mut a = WorkerStats::new();
        let mut b = WorkerStats::new();
        a.ops = 10;
        a.acked_puts = 3;
        a.corrected.record(100);
        b.ops = 5;
        b.errors = 1;
        b.aborted_workers = 1;
        b.corrected.record(200);
        a.merge(&b);
        assert_eq!(a.ops, 15);
        assert_eq!(a.errors, 1);
        assert_eq!(a.aborted_workers, 1);
        assert_eq!(a.acked_puts, 3);
        assert_eq!(a.corrected.count(), 2);
    }

    #[test]
    fn render_mentions_the_percentiles() {
        let r = sample_report().render();
        assert!(r.contains("p50="), "{r}");
        assert!(r.contains("p999="), "{r}");
        assert!(r.contains("throughput=500 ops/s"), "{r}");
        assert!(r.contains("KILL 3"), "{r}");
    }

    #[test]
    fn table_row_matches_columns() {
        let t = sample_report().to_table();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0].len(), t.columns.len());
        let csv = t.to_csv();
        assert!(csv.starts_with("mode,workload,churn"), "{csv}");
    }

    #[test]
    fn json_is_well_formed_enough() {
        let j = sample_report().to_json();
        assert!(j.contains("\"p99\""), "{j}");
        assert!(j.contains("\"churn_events\""), "{j}");
        assert!(j.contains("\"admin_rtt_ns\": 84000"), "{j}");
        assert!(j.contains("\"drain_ms\": 3.200"), "{j}");
        assert!(j.contains("\"epoch\": 1"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn events_table_rows_match_events() {
        let rep = sample_report();
        let t = rep.events_table().expect("one churn event");
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], "500");
        assert_eq!(t.rows[0][1], "kill");
        assert_eq!(t.rows[0][2], "1");
        assert_eq!(t.rows[0][3], "84.0");
        assert_eq!(t.rows[0][4], "3.200");
        let csv = t.to_csv();
        assert!(csv.starts_with("offset_ms,action,epoch,admin_rtt_us,drain_ms"), "{csv}");
        // A run without churn has no events table.
        let mut rep = rep;
        rep.churn_events.clear();
        assert!(rep.events_table().is_none());
    }

    #[test]
    fn render_summarizes_the_availability_window() {
        let r = sample_report().render();
        assert!(r.contains("availability:"), "{r}");
        assert!(r.contains("drain max=3.2ms"), "{r}");
    }

    #[test]
    fn node_load_parses_the_wire_token() {
        let n = NodeLoad::parse("node-7:4:4:1234:900:100").unwrap();
        assert_eq!(n.node, "node-7");
        assert_eq!((n.weight, n.buckets), (4, 4));
        assert_eq!((n.records, n.gets, n.puts), (1234, 900, 100));
        assert_eq!(n.ops(), 1000);
        assert!((n.observed_share(2000) - 0.5).abs() < 1e-9);
        assert!(NodeLoad::parse("node-7:4:4:1234:900").is_none(), "short token");
        assert!(NodeLoad::parse("node-7:4:4:1234:900:100:9").is_none(), "long token");
        assert!(NodeLoad::parse("node-7:x:4:1234:900:100").is_none(), "non-numeric");
    }

    #[test]
    fn render_and_csv_show_observed_load_vs_weight() {
        let rep = sample_report();
        let r = rep.render();
        // node-0 carries weight 3 of 4 → want 0.75, observed 600/800.
        assert!(r.contains("per-node load"), "{r}");
        assert!(r.contains("node-0"), "{r}");
        assert!(r.contains("want=0.750"), "{r}");
        assert!(r.contains("weighted balance: max relative error="), "{r}");
        let t = rep.node_table().expect("two node loads");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "node-0");
        assert_eq!(t.rows[0][1], "3");
        assert_eq!(t.rows[0][6], "0.7500", "600 of 800 ops");
        assert_eq!(t.rows[0][7], "0.7500", "weight 3 of 4");
        assert_eq!(t.rows[0][8], "+0.0000");
        let csv = t.to_csv();
        assert!(csv.starts_with("node,weight,buckets,records"), "{csv}");
        // No node loads → no table, no render section.
        let mut rep = rep;
        rep.node_loads.clear();
        assert!(rep.node_table().is_none());
        assert!(!rep.render().contains("per-node load"));
    }
}
