//! Run reporting: per-thread stats merged into one report, rendered for
//! humans and emitted as CSV (via [`crate::benchkit::report`]) and JSON so
//! results land in the benchmark trajectory next to the figure CSVs.

use super::churn::ChurnEvent;
use crate::benchkit::{self, report::Table};
use crate::metrics::Histogram;
use std::time::Duration;

/// One node's end-of-run load sample, parsed from the service's `NODES`
/// reply (`name:weight:buckets:records:gets:puts`). The interesting
/// figure for weighted clusters is observed share vs configured weight
/// share — see [`NodeLoad::observed_share`] / [`RunReport::node_table`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeLoad {
    /// Node display name.
    pub node: String,
    /// Configured weight.
    pub weight: u32,
    /// Bound bucket count at sample time.
    pub buckets: u32,
    /// Records held at sample time.
    pub records: u64,
    /// GETs served.
    pub gets: u64,
    /// PUTs served.
    pub puts: u64,
}

impl NodeLoad {
    /// Parse one `name:weight:buckets:records:gets:puts` token.
    pub fn parse(token: &str) -> Option<NodeLoad> {
        let mut f = token.split(':');
        let node = f.next()?.to_string();
        let mut num = || f.next()?.parse::<u64>().ok();
        let (weight, buckets, records, gets, puts) = (num()?, num()?, num()?, num()?, num()?);
        if f.next().is_some() {
            return None;
        }
        Some(NodeLoad {
            node,
            weight: weight as u32,
            buckets: buckets as u32,
            records,
            gets,
            puts,
        })
    }

    /// Operations this node served (GET + PUT).
    pub fn ops(&self) -> u64 {
        self.gets + self.puts
    }

    /// This node's share of `total_ops`.
    pub fn observed_share(&self, total_ops: u64) -> f64 {
        self.ops() as f64 / total_ops.max(1) as f64
    }
}

/// One mid-run scrape of the service's observability surface: the
/// `MSAMPLE` scalar snapshot plus the `STAGES` per-stage latency line,
/// stamped with the scraper's offset from run start. A sequence of these
/// is what lets a report *attribute* a latency spike: the sample where
/// `epochs` jumps is the churn event, and the stage whose p999 moves with
/// it names the culprit.
#[derive(Debug, Clone)]
pub struct TimeSample {
    /// Milliseconds since the run started (scraper clock, not the
    /// service's registry clock).
    pub offset_ms: u64,
    /// `metric=value` pairs from `MSAMPLE`, in wire order.
    pub scalars: Vec<(String, u64)>,
    /// Per-stage cumulative latency snapshots from `STAGES`.
    pub stages: Vec<StageSnap>,
}

impl TimeSample {
    /// Look up one scalar by its full exposition name.
    pub fn scalar(&self, name: &str) -> Option<u64> {
        self.scalars.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// One stage's cumulative histogram summary parsed from a `STAGES` token
/// (`route:n=12,mean=140,p50=120,p99=300,p999=410`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSnap {
    /// Stage name (`route`, `wal_append`, `mig_install`, …).
    pub stage: String,
    /// Samples recorded so far.
    pub n: u64,
    /// Mean latency in ns.
    pub mean_ns: u64,
    /// Median latency in ns.
    pub p50_ns: u64,
    /// 99th-percentile latency in ns.
    pub p99_ns: u64,
    /// 99.9th-percentile latency in ns.
    pub p999_ns: u64,
}

/// Parse an `MSAMPLE` reply (`OK t=<ms> <metric>=<v> …`) into scalar
/// pairs; the registry's own `t=` stamp is dropped in favor of the
/// scraper's run-relative offset. Returns `None` on a non-OK reply.
pub fn parse_msample(line: &str) -> Option<Vec<(String, u64)>> {
    let rest = line.strip_prefix("OK ")?;
    let mut out = Vec::new();
    for tok in rest.split_whitespace() {
        let (name, val) = tok.split_once('=')?;
        if name == "t" {
            continue;
        }
        out.push((name.to_string(), val.parse().ok()?));
    }
    Some(out)
}

/// Parse a `STAGES` reply into per-stage snapshots. Returns `None` on a
/// non-STAGES reply; unparseable tokens are skipped, not fatal — a
/// half-understood scrape is still a scrape.
pub fn parse_stages(line: &str) -> Option<Vec<StageSnap>> {
    let rest = line.strip_prefix("STAGES")?;
    let mut out = Vec::new();
    for tok in rest.split_whitespace() {
        let Some((stage, fields)) = tok.split_once(':') else { continue };
        let mut snap = StageSnap {
            stage: stage.to_string(),
            n: 0,
            mean_ns: 0,
            p50_ns: 0,
            p99_ns: 0,
            p999_ns: 0,
        };
        let mut ok = true;
        for kv in fields.split(',') {
            let Some((k, v)) = kv.split_once('=') else {
                ok = false;
                break;
            };
            let Ok(v) = v.parse::<u64>() else {
                ok = false;
                break;
            };
            match k {
                "n" => snap.n = v,
                "mean" => snap.mean_ns = v,
                "p50" => snap.p50_ns = v,
                "p99" => snap.p99_ns = v,
                "p999" => snap.p999_ns = v,
                _ => {}
            }
        }
        if ok {
            out.push(snap);
        }
    }
    Some(out)
}

/// One node's computed balance figures: observed traffic share vs the
/// weight share it should carry. Produced by `RunReport::balance_rows`.
#[derive(Debug, Clone, Copy)]
struct BalanceRow {
    /// The node's share of all sampled operations.
    observed: f64,
    /// `weight / Σweights` — the share the configuration asks for.
    want: f64,
}

impl BalanceRow {
    /// Signed absolute error (`observed - want`).
    fn err(&self) -> f64 {
        self.observed - self.want
    }

    /// Relative error `|observed - want| / want`, guarded against a
    /// zero weight share.
    fn rel_err(&self) -> f64 {
        self.err().abs() / self.want.max(f64::EPSILON)
    }
}

/// What one worker thread measured. Merged across threads at the end of a
/// run via [`Histogram::merge`].
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Successfully answered operations.
    pub ops: u64,
    /// Errored operations (`ERR …`, empty responses, transport failures).
    pub errors: u64,
    /// Workers that lost their transport mid-run and abandoned the rest
    /// of their schedule (1 for a single worker's stats; summed on merge).
    pub aborted_workers: u64,
    /// PUTs acknowledged with `OK` (the writes a durability check must
    /// find again).
    pub acked_puts: u64,
    /// Latency measured from the *intended* arrival time (coordinated-
    /// omission-corrected; equals `naive` in closed-loop mode).
    pub corrected: Histogram,
    /// Latency measured from the actual send time.
    pub naive: Histogram,
    /// Per-second `(ok, err)` operation buckets indexed by whole seconds
    /// since run start: slot `i` counts operations *sent* during second
    /// `i`. This is what turns a fault drill's "the cluster stayed up"
    /// into a measured per-second success rate — a crash or partition
    /// window reads as a dip in the trajectory (DESIGN.md §15).
    pub per_second: Vec<(u64, u64)>,
}

impl WorkerStats {
    /// Empty stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one operation outcome in its per-second availability
    /// bucket, growing the trajectory as the run progresses.
    pub fn record_second(&mut self, second: u64, ok: bool) {
        let idx = second as usize;
        if self.per_second.len() <= idx {
            self.per_second.resize(idx + 1, (0, 0));
        }
        let slot = &mut self.per_second[idx];
        if ok {
            slot.0 += 1;
        } else {
            slot.1 += 1;
        }
    }

    /// Fold another worker's stats into this one.
    pub fn merge(&mut self, other: &WorkerStats) {
        self.ops += other.ops;
        self.errors += other.errors;
        self.aborted_workers += other.aborted_workers;
        self.acked_puts += other.acked_puts;
        self.corrected.merge(&other.corrected);
        self.naive.merge(&other.naive);
        if self.per_second.len() < other.per_second.len() {
            self.per_second.resize(other.per_second.len(), (0, 0));
        }
        for (i, (ok, err)) in other.per_second.iter().enumerate() {
            self.per_second[i].0 += ok;
            self.per_second[i].1 += err;
        }
    }
}

/// The merged result of one loadgen run.
#[derive(Debug)]
pub struct RunReport {
    /// Generator mode (`closed` / `open`).
    pub mode: String,
    /// Workload name.
    pub workload: String,
    /// Churn scenario name.
    pub churn: String,
    /// Worker thread count.
    pub threads: usize,
    /// Open-loop target rate in ops/s (0 for closed-loop).
    pub target_rate: f64,
    /// Wall-clock run length (includes backlog drain past the schedule).
    pub elapsed: Duration,
    /// Successfully answered operations across all threads.
    pub ops: u64,
    /// Errored operations across all threads.
    pub errors: u64,
    /// Workers that lost their transport and abandoned their schedule —
    /// nonzero means the offered load fell short of the configured rate.
    pub aborted_workers: u64,
    /// PUTs acknowledged with `OK`.
    pub acked_puts: u64,
    /// Merged CO-corrected latency histogram (nanoseconds).
    pub corrected: Histogram,
    /// Merged naive (send-to-response) latency histogram (nanoseconds).
    pub naive: Histogram,
    /// Structured churn events with the measured availability window
    /// (epoch, admin rtt, drain time) and the human log line — see
    /// [`ChurnEvent`].
    pub churn_events: Vec<ChurnEvent>,
    /// End-of-run per-node load (from the `NODES` protocol command):
    /// observed load vs configured weight, so weighted runs show balance
    /// error end to end. Empty when the target did not answer `NODES`.
    pub node_loads: Vec<NodeLoad>,
    /// Mid-run scrapes of `MSAMPLE` + `STAGES` at a fixed cadence: the
    /// time axis that attributes a latency spike to a churn event and a
    /// named stage. Empty when the target did not answer the scrapes.
    pub timeseries: Vec<TimeSample>,
    /// Per-second `(ok, err)` buckets merged across workers — the
    /// availability trajectory. Second `i` covers `[i, i+1)` seconds
    /// after run start; the per-second success rate is the drill-facing
    /// availability figure (a fault window reads as a dip).
    pub availability: Vec<(u64, u64)>,
}

impl RunReport {
    /// Achieved throughput in ops/s.
    pub fn throughput(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.ops as f64 / secs
        } else {
            0.0
        }
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let q = |h: &Histogram, p: f64| benchkit::fmt_ns(h.quantile(p) as f64);
        let mut out = String::new();
        out.push_str("== loadgen report ==\n");
        out.push_str(&format!(
            "mode={} workload={} churn={} threads={}",
            self.mode, self.workload, self.churn, self.threads
        ));
        if self.target_rate > 0.0 {
            out.push_str(&format!(" rate={:.0}/s", self.target_rate));
        }
        out.push('\n');
        out.push_str(&format!(
            "elapsed={:.2?} ops={} errors={} acked_puts={} throughput={:.0} ops/s\n",
            self.elapsed,
            self.ops,
            self.errors,
            self.acked_puts,
            self.throughput()
        ));
        if self.aborted_workers > 0 {
            out.push_str(&format!(
                "WARNING: {} of {} workers lost their connection and abandoned \
                 their schedule — offered load fell short of the target\n",
                self.aborted_workers, self.threads
            ));
        }
        out.push_str(&format!(
            "latency (CO-corrected): p50={} p90={} p99={} p999={} max={}\n",
            q(&self.corrected, 0.5),
            q(&self.corrected, 0.9),
            q(&self.corrected, 0.99),
            q(&self.corrected, 0.999),
            benchkit::fmt_ns(self.corrected.max() as f64)
        ));
        out.push_str(&format!(
            "latency (naive):        p50={} p90={} p99={} p999={} max={}\n",
            q(&self.naive, 0.5),
            q(&self.naive, 0.9),
            q(&self.naive, 0.99),
            q(&self.naive, 0.999),
            benchkit::fmt_ns(self.naive.max() as f64)
        ));
        if let Some((sec, rate)) = self.min_availability() {
            out.push_str(&format!(
                "availability (per-second): min success rate={:.4} at t={}s over {} seconds\n",
                rate,
                sec,
                self.availability.len()
            ));
        }
        if !self.node_loads.is_empty() {
            out.push_str("per-node load (observed share vs weight share):\n");
            let mut err_max = 0.0f64;
            for (n, b) in self.balance_rows() {
                err_max = err_max.max(b.rel_err());
                out.push_str(&format!(
                    "  {:<10} w={:<2} buckets={:<2} records={:<7} ops={:<8} \
                     share={:.3} want={:.3} err={:+.3}\n",
                    n.node,
                    n.weight,
                    n.buckets,
                    n.records,
                    n.ops(),
                    b.observed,
                    b.want,
                    b.err()
                ));
            }
            out.push_str(&format!("weighted balance: max relative error={err_max:.3}\n"));
        }
        if !self.timeseries.is_empty() {
            out.push_str("time series (cumulative stage p999, scraped mid-run):\n");
            for s in &self.timeseries {
                let lookups = s.scalar("memento_router_lookups_scalar").unwrap_or(0);
                let epochs = s.scalar("memento_router_epochs").unwrap_or(0);
                out.push_str(&format!(
                    "  [t={:>5}ms] lookups={lookups} epochs={epochs}",
                    s.offset_ms
                ));
                for st in s.stages.iter().filter(|st| st.n > 0) {
                    out.push_str(&format!(" {}.p999={}", st.stage, st.p999_ns));
                }
                out.push('\n');
            }
        }
        if !self.churn_events.is_empty() {
            out.push_str("churn events:\n");
            for e in &self.churn_events {
                out.push_str(&format!("  {}\n", e.line));
            }
            let rtts: Vec<u64> =
                self.churn_events.iter().map(|e| e.admin_rtt_ns).filter(|&n| n > 0).collect();
            let drains: Vec<f64> = self.churn_events.iter().filter_map(|e| e.drain_ms).collect();
            if let Some(&max_rtt) = rtts.iter().max() {
                out.push_str(&format!(
                    "availability: admin_rtt max={} over {} events",
                    benchkit::fmt_ns(max_rtt as f64),
                    rtts.len()
                ));
                if !drains.is_empty() {
                    let max_drain = drains.iter().copied().fold(f64::MIN, f64::max);
                    out.push_str(&format!(
                        ", drain max={max_drain:.1}ms ({} measured)",
                        drains.len()
                    ));
                }
                out.push('\n');
            }
        }
        out
    }

    /// Lowest per-second success rate across the run, with the second it
    /// occurred in (`None` when no second saw traffic). This is the
    /// availability floor a fault drill gates on: a crash or partition
    /// that stalls the data path shows up here even when the run-total
    /// error ratio stays tiny.
    pub fn min_availability(&self) -> Option<(u64, f64)> {
        self.availability
            .iter()
            .enumerate()
            .filter(|(_, (ok, err))| ok + err > 0)
            .map(|(s, (ok, err))| (s as u64, *ok as f64 / (ok + err) as f64))
            .min_by(|a, b| a.1.total_cmp(&b.1))
    }

    /// Per-second success-rate table for the `results/` CSV trajectory
    /// (`None` when the run collected no per-second buckets). A second
    /// with no traffic at all emits rate 1.0 — no evidence of
    /// unavailability is not the same as failure, and workers that sat
    /// out a second (open-loop pacing gaps) should not read as an outage.
    pub fn availability_table(&self) -> Option<Table> {
        if self.availability.is_empty() {
            return None;
        }
        let mut t =
            Table::new("loadgen_availability", &["second", "ok", "err", "success_rate"]);
        for (s, (ok, err)) in self.availability.iter().enumerate() {
            let total = ok + err;
            let rate = if total > 0 { *ok as f64 / total as f64 } else { 1.0 };
            t.push_row(vec![
                s.to_string(),
                ok.to_string(),
                err.to_string(),
                format!("{rate:.4}"),
            ]);
        }
        Some(t)
    }

    /// Per-event availability table for the `results/` CSV trajectory
    /// (`None` when the run had no churn). Unmeasured drains emit -1.
    pub fn events_table(&self) -> Option<Table> {
        if self.churn_events.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "loadgen_churn_events",
            &["offset_ms", "action", "epoch", "admin_rtt_us", "drain_ms"],
        );
        for e in &self.churn_events {
            t.push_row(vec![
                e.offset_ms.to_string(),
                e.action.to_string(),
                e.epoch.to_string(),
                format!("{:.1}", e.admin_rtt_ns as f64 / 1e3),
                e.drain_ms.map_or("-1".to_string(), |d| format!("{d:.3}")),
            ]);
        }
        Some(t)
    }

    /// The mid-run scrape trajectory for the `results/` CSV trajectory
    /// (`None` when the run collected no samples). One row per (sample,
    /// active stage): the `offset_ms`/`epochs_total` columns line a row
    /// up with the churn events table, `ops_per_s` is the lookup-counter
    /// delta against the previous sample, and the stage columns carry
    /// that stage's cumulative latency summary — so a post-kill p999
    /// spike reads straight off the CSV with its stage name attached.
    pub fn timeseries_table(&self) -> Option<Table> {
        if self.timeseries.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "loadgen_timeseries",
            &[
                "offset_ms", "lookups_total", "epochs_total", "ops_per_s", "stage", "n",
                "mean_ns", "p50_ns", "p99_ns", "p999_ns",
            ],
        );
        let mut prev: Option<(u64, u64)> = None; // (offset_ms, lookups)
        for s in &self.timeseries {
            let lookups = s.scalar("memento_router_lookups_scalar").unwrap_or(0);
            let epochs = s.scalar("memento_router_epochs").unwrap_or(0);
            let rate = match prev {
                Some((t0, l0)) if s.offset_ms > t0 => {
                    lookups.saturating_sub(l0) as f64 * 1e3 / (s.offset_ms - t0) as f64
                }
                _ => 0.0,
            };
            prev = Some((s.offset_ms, lookups));
            let active: Vec<&StageSnap> = s.stages.iter().filter(|st| st.n > 0).collect();
            let mut push = |stage: &str, n: u64, mean: u64, p50: u64, p99: u64, p999: u64| {
                t.push_row(vec![
                    s.offset_ms.to_string(),
                    lookups.to_string(),
                    epochs.to_string(),
                    format!("{rate:.0}"),
                    stage.to_string(),
                    n.to_string(),
                    mean.to_string(),
                    p50.to_string(),
                    p99.to_string(),
                    p999.to_string(),
                ]);
            };
            if active.is_empty() {
                push("-", 0, 0, 0, 0, 0);
            } else {
                for st in active {
                    push(&st.stage, st.n, st.mean_ns, st.p50_ns, st.p99_ns, st.p999_ns);
                }
            }
        }
        Some(t)
    }

    /// Per-node balance figures (observed share vs weight share), the
    /// single source both [`RunReport::render`] and
    /// [`RunReport::node_table`] consume so the definition cannot drift
    /// between the human and CSV views.
    fn balance_rows(&self) -> Vec<(&NodeLoad, BalanceRow)> {
        let total_ops: u64 = self.node_loads.iter().map(|n| n.ops()).sum();
        let total_weight: u64 = self.node_loads.iter().map(|n| u64::from(n.weight)).sum();
        self.node_loads
            .iter()
            .map(|n| {
                let observed = n.observed_share(total_ops);
                let want = f64::from(n.weight) / total_weight.max(1) as f64;
                (n, BalanceRow { observed, want })
            })
            .collect()
    }

    /// Per-node observed-load vs configured-weight table for the
    /// `results/` CSV trajectory (`None` when the run collected no node
    /// loads).
    pub fn node_table(&self) -> Option<Table> {
        if self.node_loads.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "loadgen_nodes",
            &[
                "node", "weight", "buckets", "records", "gets", "puts", "observed_share",
                "weight_share", "balance_err",
            ],
        );
        for (n, b) in self.balance_rows() {
            t.push_row(vec![
                n.node.clone(),
                n.weight.to_string(),
                n.buckets.to_string(),
                n.records.to_string(),
                n.gets.to_string(),
                n.puts.to_string(),
                format!("{:.4}", b.observed),
                format!("{:.4}", b.want),
                format!("{:+.4}", b.err()),
            ]);
        }
        Some(t)
    }

    /// One-row table for the CSV trajectory under `results/`.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "loadgen",
            &[
                "mode", "workload", "churn", "threads", "rate", "elapsed_s", "ops", "errors",
                "throughput", "p50_ns", "p90_ns", "p99_ns", "p999_ns", "max_ns", "naive_p99_ns",
            ],
        );
        t.push_row(vec![
            self.mode.clone(),
            self.workload.clone(),
            self.churn.clone(),
            self.threads.to_string(),
            format!("{:.0}", self.target_rate),
            format!("{:.3}", self.elapsed.as_secs_f64()),
            self.ops.to_string(),
            self.errors.to_string(),
            format!("{:.0}", self.throughput()),
            self.corrected.quantile(0.5).to_string(),
            self.corrected.quantile(0.9).to_string(),
            self.corrected.quantile(0.99).to_string(),
            self.corrected.quantile(0.999).to_string(),
            self.corrected.max().to_string(),
            self.naive.quantile(0.99).to_string(),
        ]);
        t
    }

    /// Serialize as a JSON object (hand-rolled; serde is not in the
    /// offline crate set).
    pub fn to_json(&self) -> String {
        let hist = |h: &Histogram| {
            format!(
                "{{\"n\": {}, \"mean_ns\": {:.1}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \
                 \"p999\": {}, \"max\": {}}}",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.9),
                h.quantile(0.99),
                h.quantile(0.999),
                h.max()
            )
        };
        let events: Vec<String> = self
            .churn_events
            .iter()
            .map(|e| {
                format!(
                    "{{\"offset_ms\": {}, \"action\": \"{}\", \"epoch\": {}, \
                     \"admin_rtt_ns\": {}, \"drain_ms\": {}, \"line\": \"{}\"}}",
                    e.offset_ms,
                    e.action,
                    e.epoch,
                    e.admin_rtt_ns,
                    e.drain_ms.map_or("null".to_string(), |d| format!("{d:.3}")),
                    json_escape(&e.line)
                )
            })
            .collect();
        let avail: Vec<String> =
            self.availability.iter().map(|(ok, err)| format!("[{ok}, {err}]")).collect();
        format!(
            "{{\n  \"mode\": \"{}\",\n  \"workload\": \"{}\",\n  \"churn\": \"{}\",\n  \
             \"threads\": {},\n  \"target_rate\": {:.1},\n  \"elapsed_s\": {:.3},\n  \
             \"ops\": {},\n  \"errors\": {},\n  \"aborted_workers\": {},\n  \
             \"acked_puts\": {},\n  \
             \"throughput\": {:.1},\n  \"latency_ns\": {},\n  \"naive_latency_ns\": {},\n  \
             \"churn_events\": [{}],\n  \"availability_per_s\": [{}],\n  \
             \"timeseries_samples\": {}\n}}\n",
            json_escape(&self.mode),
            json_escape(&self.workload),
            json_escape(&self.churn),
            self.threads,
            self.target_rate,
            self.elapsed.as_secs_f64(),
            self.ops,
            self.errors,
            self.aborted_workers,
            self.acked_puts,
            self.throughput(),
            hist(&self.corrected),
            hist(&self.naive),
            events.join(", "),
            avail.join(", "),
            self.timeseries.len()
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> RunReport {
        let mut corrected = Histogram::new();
        let mut naive = Histogram::new();
        for i in 1..=1000u64 {
            corrected.record(i * 1000);
            naive.record(i * 500);
        }
        RunReport {
            mode: "open".into(),
            workload: "zipf".into(),
            churn: "incremental".into(),
            threads: 4,
            target_rate: 10_000.0,
            elapsed: Duration::from_secs(2),
            ops: 1000,
            errors: 0,
            aborted_workers: 0,
            acked_puts: 300,
            corrected,
            naive,
            churn_events: vec![ChurnEvent {
                offset_ms: 500,
                action: "kill",
                epoch: 1,
                admin_rtt_ns: 84_000,
                drain_ms: Some(3.2),
                line: "[500ms] KILL 3 -> KILLED node-3 EPOCH 1 SOURCES 1".into(),
            }],
            node_loads: vec![
                NodeLoad {
                    node: "node-0".into(),
                    weight: 3,
                    buckets: 3,
                    records: 600,
                    gets: 450,
                    puts: 150,
                },
                NodeLoad {
                    node: "node-1".into(),
                    weight: 1,
                    buckets: 1,
                    records: 200,
                    gets: 150,
                    puts: 50,
                },
            ],
            timeseries: vec![
                TimeSample {
                    offset_ms: 250,
                    scalars: vec![
                        ("memento_router_lookups_scalar".into(), 400),
                        ("memento_router_epochs".into(), 0),
                    ],
                    stages: vec![StageSnap {
                        stage: "route".into(),
                        n: 6,
                        mean_ns: 140,
                        p50_ns: 120,
                        p99_ns: 300,
                        p999_ns: 410,
                    }],
                },
                TimeSample {
                    offset_ms: 750,
                    scalars: vec![
                        ("memento_router_lookups_scalar".into(), 900),
                        ("memento_router_epochs".into(), 1),
                    ],
                    stages: vec![StageSnap {
                        stage: "route".into(),
                        n: 14,
                        mean_ns: 500,
                        p50_ns: 130,
                        p99_ns: 2_000,
                        p999_ns: 9_000,
                    }],
                },
            ],
            availability: vec![(500, 0), (480, 20)],
        }
    }

    #[test]
    fn worker_stats_merge_accumulates() {
        let mut a = WorkerStats::new();
        let mut b = WorkerStats::new();
        a.ops = 10;
        a.acked_puts = 3;
        a.corrected.record(100);
        b.ops = 5;
        b.errors = 1;
        b.aborted_workers = 1;
        b.corrected.record(200);
        a.merge(&b);
        assert_eq!(a.ops, 15);
        assert_eq!(a.errors, 1);
        assert_eq!(a.aborted_workers, 1);
        assert_eq!(a.acked_puts, 3);
        assert_eq!(a.corrected.count(), 2);
    }

    #[test]
    fn per_second_buckets_merge_elementwise() {
        let mut a = WorkerStats::new();
        let mut b = WorkerStats::new();
        a.record_second(0, true);
        a.record_second(2, false);
        b.record_second(1, true);
        b.record_second(2, true);
        a.merge(&b);
        assert_eq!(a.per_second, vec![(1, 0), (1, 0), (1, 1)]);
    }

    #[test]
    fn availability_trajectory_renders_tables_and_json() {
        let rep = sample_report();
        let (sec, rate) = rep.min_availability().expect("two seconds of traffic");
        assert_eq!(sec, 1, "second 1 has the errors");
        assert!((rate - 0.96).abs() < 1e-9, "480/500 = {rate}");
        let t = rep.availability_table().expect("two buckets");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[1][0], "1");
        assert_eq!(t.rows[1][1], "480");
        assert_eq!(t.rows[1][2], "20");
        assert_eq!(t.rows[1][3], "0.9600");
        let csv = t.to_csv();
        assert!(csv.starts_with("second,ok,err,success_rate"), "{csv}");
        let r = rep.render();
        assert!(
            r.contains("availability (per-second): min success rate=0.9600 at t=1s"),
            "{r}"
        );
        assert!(
            rep.to_json().contains("\"availability_per_s\": [[500, 0], [480, 20]]"),
            "{}",
            rep.to_json()
        );
        // A traffic-free second reads as available, not as an outage.
        let mut rep = rep;
        rep.availability.insert(1, (0, 0));
        assert_eq!(rep.availability_table().unwrap().rows[1][3], "1.0000");
        assert_eq!(rep.min_availability().unwrap().0, 2, "the dip moved to second 2");
        // No buckets at all → no table, no render section, no min.
        rep.availability.clear();
        assert!(rep.availability_table().is_none());
        assert!(rep.min_availability().is_none());
        assert!(!rep.render().contains("availability (per-second)"));
    }

    #[test]
    fn render_mentions_the_percentiles() {
        let r = sample_report().render();
        assert!(r.contains("p50="), "{r}");
        assert!(r.contains("p999="), "{r}");
        assert!(r.contains("throughput=500 ops/s"), "{r}");
        assert!(r.contains("KILL 3"), "{r}");
    }

    #[test]
    fn table_row_matches_columns() {
        let t = sample_report().to_table();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0].len(), t.columns.len());
        let csv = t.to_csv();
        assert!(csv.starts_with("mode,workload,churn"), "{csv}");
    }

    #[test]
    fn json_is_well_formed_enough() {
        let j = sample_report().to_json();
        assert!(j.contains("\"p99\""), "{j}");
        assert!(j.contains("\"churn_events\""), "{j}");
        assert!(j.contains("\"admin_rtt_ns\": 84000"), "{j}");
        assert!(j.contains("\"drain_ms\": 3.200"), "{j}");
        assert!(j.contains("\"epoch\": 1"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn events_table_rows_match_events() {
        let rep = sample_report();
        let t = rep.events_table().expect("one churn event");
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0][0], "500");
        assert_eq!(t.rows[0][1], "kill");
        assert_eq!(t.rows[0][2], "1");
        assert_eq!(t.rows[0][3], "84.0");
        assert_eq!(t.rows[0][4], "3.200");
        let csv = t.to_csv();
        assert!(csv.starts_with("offset_ms,action,epoch,admin_rtt_us,drain_ms"), "{csv}");
        // A run without churn has no events table.
        let mut rep = rep;
        rep.churn_events.clear();
        assert!(rep.events_table().is_none());
    }

    #[test]
    fn render_summarizes_the_availability_window() {
        let r = sample_report().render();
        assert!(r.contains("availability:"), "{r}");
        assert!(r.contains("drain max=3.2ms"), "{r}");
    }

    #[test]
    fn msample_and_stages_parse_the_wire_lines() {
        let scalars =
            parse_msample("OK t=1234 memento_router_lookups_scalar=42 memento_wal_appends=7")
                .unwrap();
        assert_eq!(scalars.len(), 2, "the t= stamp is dropped: {scalars:?}");
        assert_eq!(scalars[0], ("memento_router_lookups_scalar".to_string(), 42));
        assert_eq!(scalars[1], ("memento_wal_appends".to_string(), 7));
        assert!(parse_msample("ERR nope").is_none());

        let stages = parse_stages(
            "STAGES route:n=12,mean=140,p50=120,p99=300,p999=410 wal_append:n=0,mean=0,p50=0,p99=0,p999=0",
        )
        .unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(
            stages[0],
            StageSnap {
                stage: "route".into(),
                n: 12,
                mean_ns: 140,
                p50_ns: 120,
                p99_ns: 300,
                p999_ns: 410,
            }
        );
        assert_eq!(stages[1].n, 0);
        assert!(parse_stages("ERR nope").is_none());
        // Unparseable tokens are skipped, not fatal.
        assert_eq!(parse_stages("STAGES garbage route:n=1,mean=2,p50=2,p99=2,p999=2")
            .unwrap()
            .len(), 1);
    }

    #[test]
    fn timeseries_table_attributes_rate_and_stage_tails() {
        let rep = sample_report();
        let t = rep.timeseries_table().expect("two samples");
        assert_eq!(t.rows.len(), 2, "one active stage per sample");
        let csv = t.to_csv();
        assert!(csv.starts_with("offset_ms,lookups_total,epochs_total,ops_per_s,stage"), "{csv}");
        // First sample has no predecessor → rate 0; second is
        // (900-400) lookups over 500 ms = 1000 ops/s.
        assert_eq!(t.rows[0][3], "0");
        assert_eq!(t.rows[1][3], "1000");
        assert_eq!(t.rows[1][2], "1", "the epoch bump rides the same row");
        assert_eq!(t.rows[1][4], "route");
        assert_eq!(t.rows[1][9], "9000", "the spike is attributable by stage");
        // The render section shows the same trajectory.
        let r = rep.render();
        assert!(r.contains("time series (cumulative stage p999"), "{r}");
        assert!(r.contains("route.p999=9000"), "{r}");
        // JSON carries the sample count.
        assert!(rep.to_json().contains("\"timeseries_samples\": 2"));
        // No samples → no table, no render section.
        let mut rep = rep;
        rep.timeseries.clear();
        assert!(rep.timeseries_table().is_none());
        assert!(!rep.render().contains("time series"));
    }

    #[test]
    fn node_load_parses_the_wire_token() {
        let n = NodeLoad::parse("node-7:4:4:1234:900:100").unwrap();
        assert_eq!(n.node, "node-7");
        assert_eq!((n.weight, n.buckets), (4, 4));
        assert_eq!((n.records, n.gets, n.puts), (1234, 900, 100));
        assert_eq!(n.ops(), 1000);
        assert!((n.observed_share(2000) - 0.5).abs() < 1e-9);
        assert!(NodeLoad::parse("node-7:4:4:1234:900").is_none(), "short token");
        assert!(NodeLoad::parse("node-7:4:4:1234:900:100:9").is_none(), "long token");
        assert!(NodeLoad::parse("node-7:x:4:1234:900:100").is_none(), "non-numeric");
    }

    #[test]
    fn render_and_csv_show_observed_load_vs_weight() {
        let rep = sample_report();
        let r = rep.render();
        // node-0 carries weight 3 of 4 → want 0.75, observed 600/800.
        assert!(r.contains("per-node load"), "{r}");
        assert!(r.contains("node-0"), "{r}");
        assert!(r.contains("want=0.750"), "{r}");
        assert!(r.contains("weighted balance: max relative error="), "{r}");
        let t = rep.node_table().expect("two node loads");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0], "node-0");
        assert_eq!(t.rows[0][1], "3");
        assert_eq!(t.rows[0][6], "0.7500", "600 of 800 ops");
        assert_eq!(t.rows[0][7], "0.7500", "weight 3 of 4");
        assert_eq!(t.rows[0][8], "+0.0000");
        let csv = t.to_csv();
        assert!(csv.starts_with("node,weight,buckets,records"), "{csv}");
        // No node loads → no table, no render section.
        let mut rep = rep;
        rep.node_loads.clear();
        assert!(rep.node_table().is_none());
        assert!(!rep.render().contains("per-node load"));
    }
}
