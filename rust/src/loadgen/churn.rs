//! Churn injection: membership changes fired mid-run, through the same
//! admin protocol a human operator would use (`KILL <bucket>` / `ADD`).
//!
//! The scenarios mirror the paper's evaluation matrix end-to-end instead
//! of at the algorithm layer:
//!
//! * **stable** — no membership changes (Figs. 17/18 shape);
//! * **oneshot** — all failures at once at the run's midpoint
//!   (Figs. 19–22 shape: a rack loss);
//! * **incremental** — failures spread across the run, then restores near
//!   the end (Figs. 23–26 shape: rolling failures + recovery).
//!
//! The injector is deliberately protocol-only: it discovers killable
//! buckets by trying ids and reading responses, so it works against any
//! live service, in-process or remote.

use super::target::Target;
use std::time::{Duration, Instant};

/// What the injector does at one scheduled point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnAction {
    /// Fail one working bucket (`KILL <b>`).
    Kill,
    /// Restore capacity (`ADD`).
    Restore,
}

/// The churn shape for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnScenario {
    /// No membership changes.
    Stable,
    /// `kills` failures at once at the midpoint of the run.
    OneShot {
        /// Number of buckets to fail.
        kills: usize,
    },
    /// `kills` failures spread across the first two thirds of the run,
    /// matched by restores near the end.
    Incremental {
        /// Number of buckets to fail (and later restore).
        kills: usize,
    },
}

impl ChurnScenario {
    /// Build by CLI name: `stable`, `oneshot`, or `incremental`.
    pub fn by_name(name: &str, kills: usize) -> Result<Self, String> {
        match name {
            "stable" => Ok(ChurnScenario::Stable),
            "oneshot" => Ok(ChurnScenario::OneShot { kills }),
            "incremental" => Ok(ChurnScenario::Incremental { kills }),
            other => Err(format!("unknown churn scenario '{other}' (stable|oneshot|incremental)")),
        }
    }

    /// The scenario's display name.
    pub fn name(&self) -> &'static str {
        match self {
            ChurnScenario::Stable => "stable",
            ChurnScenario::OneShot { .. } => "oneshot",
            ChurnScenario::Incremental { .. } => "incremental",
        }
    }

    /// The event schedule for a run of the given length, sorted by offset.
    pub fn plan(&self, duration: Duration) -> Vec<(Duration, ChurnAction)> {
        let at = |frac: f64| duration.mul_f64(frac);
        match *self {
            ChurnScenario::Stable => Vec::new(),
            ChurnScenario::OneShot { kills } => {
                (0..kills).map(|_| (at(0.5), ChurnAction::Kill)).collect()
            }
            ChurnScenario::Incremental { kills } => {
                let mut plan = Vec::with_capacity(2 * kills);
                // Failures accumulate through [15%, 65%] of the run…
                for i in 0..kills {
                    let frac = 0.15 + 0.5 * i as f64 / kills.max(1) as f64;
                    plan.push((at(frac), ChurnAction::Kill));
                }
                // …then capacity returns through [75%, 95%].
                for i in 0..kills {
                    let frac = 0.75 + 0.2 * i as f64 / kills.max(1) as f64;
                    plan.push((at(frac), ChurnAction::Restore));
                }
                plan
            }
        }
    }
}

/// Drive `plan` against an admin connection. `buckets` bounds the bucket
/// ids probed for `KILL` (pass the initial cluster size). Returns a log of
/// what actually happened, one line per event.
pub fn inject(
    mut admin: Box<dyn Target>,
    plan: &[(Duration, ChurnAction)],
    start: Instant,
    buckets: u32,
) -> Vec<String> {
    let mut log = Vec::with_capacity(plan.len());
    let mut cursor = 0u32;
    for (at, action) in plan {
        let elapsed = start.elapsed();
        if *at > elapsed {
            std::thread::sleep(*at - elapsed);
        }
        let stamp = start.elapsed().as_millis();
        match action {
            ChurnAction::Kill => {
                // Probe bucket ids until one KILL is accepted (a bucket may
                // already be down; the service answers ERR and we move on).
                let mut killed = false;
                for _ in 0..buckets.max(1) {
                    let b = cursor % buckets.max(1);
                    cursor = cursor.wrapping_add(1);
                    match admin.call(&format!("KILL {b}")) {
                        Ok(r) if r.starts_with("KILLED") => {
                            log.push(format!("[{stamp}ms] KILL {b} -> {r}"));
                            killed = true;
                            break;
                        }
                        Ok(_) => continue,
                        Err(e) => {
                            log.push(format!("[{stamp}ms] admin connection lost: {e}"));
                            return log;
                        }
                    }
                }
                if !killed {
                    log.push(format!("[{stamp}ms] KILL skipped: no killable bucket"));
                }
            }
            ChurnAction::Restore => match admin.call("ADD") {
                Ok(r) => log.push(format!("[{stamp}ms] ADD -> {r}")),
                Err(e) => {
                    log.push(format!("[{stamp}ms] admin connection lost: {e}"));
                    return log;
                }
            },
        }
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_plans_nothing() {
        assert!(ChurnScenario::Stable.plan(Duration::from_secs(1)).is_empty());
    }

    #[test]
    fn oneshot_fires_everything_at_the_midpoint() {
        let plan = ChurnScenario::OneShot { kills: 3 }.plan(Duration::from_secs(2));
        assert_eq!(plan.len(), 3);
        for (at, action) in &plan {
            assert_eq!(*at, Duration::from_secs(1));
            assert_eq!(*action, ChurnAction::Kill);
        }
    }

    #[test]
    fn incremental_spreads_kills_then_restores() {
        let plan = ChurnScenario::Incremental { kills: 4 }.plan(Duration::from_secs(10));
        assert_eq!(plan.len(), 8);
        let kills: Vec<_> =
            plan.iter().filter(|(_, a)| *a == ChurnAction::Kill).map(|(t, _)| *t).collect();
        let restores: Vec<_> =
            plan.iter().filter(|(_, a)| *a == ChurnAction::Restore).map(|(t, _)| *t).collect();
        assert_eq!(kills.len(), 4);
        assert_eq!(restores.len(), 4);
        assert!(kills.windows(2).all(|w| w[0] < w[1]), "kills in order");
        assert!(kills.last().unwrap() < restores.first().unwrap(), "kills before restores");
        assert!(*restores.last().unwrap() < Duration::from_secs(10));
    }

    #[test]
    fn by_name_round_trips() {
        for name in ["stable", "oneshot", "incremental"] {
            assert_eq!(ChurnScenario::by_name(name, 2).unwrap().name(), name);
        }
        assert!(ChurnScenario::by_name("thundering-herd", 2).is_err());
    }

    #[test]
    fn inject_drives_a_live_service() {
        use crate::coordinator::router::Router;
        use crate::coordinator::service::Service;
        let router = Router::new("memento", 6, 60, None).unwrap();
        let svc = Service::new(router.clone());
        let admin = Box::new(super::super::target::InProcTarget::new(svc));
        let plan = vec![
            (Duration::ZERO, ChurnAction::Kill),
            (Duration::ZERO, ChurnAction::Kill),
            (Duration::ZERO, ChurnAction::Restore),
        ];
        let log = inject(admin, &plan, Instant::now(), 6);
        assert_eq!(log.len(), 3, "{log:?}");
        assert!(log[0].contains("KILLED"), "{}", log[0]);
        assert!(log[1].contains("KILLED"), "{}", log[1]);
        assert!(log[2].contains("ADDED"), "{}", log[2]);
        assert_eq!(router.working(), 5);
    }
}
