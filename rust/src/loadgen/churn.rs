//! Churn injection: membership changes fired mid-run, through the same
//! admin protocol a human operator would use (`KILL <bucket>` / `ADD`).
//!
//! The scenarios mirror the paper's evaluation matrix end-to-end instead
//! of at the algorithm layer:
//!
//! * **stable** — no membership changes (Figs. 17/18 shape);
//! * **oneshot** — all failures at once at the run's midpoint
//!   (Figs. 19–22 shape: a rack loss);
//! * **incremental** — failures spread across the run, then restores near
//!   the end (Figs. 23–26 shape: rolling failures + recovery).
//!
//! The injector is deliberately protocol-only: it discovers killable
//! buckets by trying ids and reading responses, so it works against any
//! live service, in-process or remote.
//!
//! Each event records the **availability window** end to end: the admin
//! round trip (the epoch publish a client waits for) and the drain time
//! until `MSTAT` reports the enqueued migration idle — the measured
//! counterpart of the O(1)-admin / background-migration split
//! (`coordinator::migration`).

use super::target::Target;
use std::time::{Duration, Instant};

/// Longest the injector polls `MSTAT` for one event's drain before
/// giving up (also capped by the next scheduled event's due time, so
/// measurement never delays the churn schedule).
const DRAIN_POLL_BUDGET: Duration = Duration::from_secs(2);

/// One executed churn event with its end-to-end availability window:
/// how long the admin command took to *ack* (the epoch publish) and how
/// long until the migration it enqueued *drained*.
#[derive(Debug, Clone)]
pub struct ChurnEvent {
    /// Offset from run start when the event fired, in milliseconds.
    pub offset_ms: u64,
    /// `kill`, `add`, `kill-skipped` or `error`.
    pub action: &'static str,
    /// Epoch the service reported for the change (0 when unparsed).
    pub epoch: u64,
    /// Admin-command round trip in nanoseconds — the epoch-publish
    /// latency a client observes (O(1) in stored keys on this stack).
    pub admin_rtt_ns: u64,
    /// Milliseconds from the admin ack until `MSTAT` reported the
    /// migration queue idle; `None` when the drain outlived the event's
    /// polling budget (or the target has no `MSTAT`).
    pub drain_ms: Option<f64>,
    /// Human-readable log line.
    pub line: String,
}

/// Parse `EPOCH <e>` out of a `KILLED …`/`ADDED …` response.
fn parse_epoch(resp: &str) -> u64 {
    let mut toks = resp.split_whitespace();
    while let Some(t) = toks.next() {
        if t == "EPOCH" {
            return toks.next().and_then(|v| v.parse().ok()).unwrap_or(0);
        }
    }
    0
}

/// Poll `MSTAT` until the migration queue reports idle; returns the
/// elapsed drain time in ms, or `None` if `budget` ran out (or the
/// target does not speak `MSTAT`).
fn measure_drain(admin: &mut Box<dyn Target>, budget: Duration) -> Option<f64> {
    let t0 = Instant::now();
    loop {
        match admin.call("MSTAT") {
            Ok(r) if r.starts_with("MSTAT") => {
                if r.contains("idle=true") {
                    return Some(t0.elapsed().as_secs_f64() * 1e3);
                }
            }
            _ => return None,
        }
        if t0.elapsed() >= budget {
            return None;
        }
        std::thread::sleep(Duration::from_micros(300));
    }
}

/// What the injector does at one scheduled point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnAction {
    /// Fail one working bucket (`KILL <b>`).
    Kill,
    /// Restore capacity (`ADD`).
    Restore,
}

/// The churn shape for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnScenario {
    /// No membership changes.
    Stable,
    /// `kills` failures at once at the midpoint of the run.
    OneShot {
        /// Number of buckets to fail.
        kills: usize,
    },
    /// `kills` failures spread across the first two thirds of the run,
    /// matched by restores near the end.
    Incremental {
        /// Number of buckets to fail (and later restore).
        kills: usize,
    },
}

impl ChurnScenario {
    /// Build by CLI name: `stable`, `oneshot`, or `incremental`.
    pub fn by_name(name: &str, kills: usize) -> Result<Self, String> {
        match name {
            "stable" => Ok(ChurnScenario::Stable),
            "oneshot" => Ok(ChurnScenario::OneShot { kills }),
            "incremental" => Ok(ChurnScenario::Incremental { kills }),
            other => Err(format!("unknown churn scenario '{other}' (stable|oneshot|incremental)")),
        }
    }

    /// The scenario's display name.
    pub fn name(&self) -> &'static str {
        match self {
            ChurnScenario::Stable => "stable",
            ChurnScenario::OneShot { .. } => "oneshot",
            ChurnScenario::Incremental { .. } => "incremental",
        }
    }

    /// The event schedule for a run of the given length, sorted by offset.
    pub fn plan(&self, duration: Duration) -> Vec<(Duration, ChurnAction)> {
        let at = |frac: f64| duration.mul_f64(frac);
        match *self {
            ChurnScenario::Stable => Vec::new(),
            ChurnScenario::OneShot { kills } => {
                (0..kills).map(|_| (at(0.5), ChurnAction::Kill)).collect()
            }
            ChurnScenario::Incremental { kills } => {
                let mut plan = Vec::with_capacity(2 * kills);
                // Failures accumulate through [15%, 65%] of the run…
                for i in 0..kills {
                    let frac = 0.15 + 0.5 * i as f64 / kills.max(1) as f64;
                    plan.push((at(frac), ChurnAction::Kill));
                }
                // …then capacity returns through [75%, 95%].
                for i in 0..kills {
                    let frac = 0.75 + 0.2 * i as f64 / kills.max(1) as f64;
                    plan.push((at(frac), ChurnAction::Restore));
                }
                plan
            }
        }
    }
}

/// Drive `plan` against an admin connection. `buckets` bounds the bucket
/// ids probed for `KILL` (pass the initial cluster size). Returns one
/// [`ChurnEvent`] per plan entry: the log line plus the measured
/// availability window — admin round trip (epoch publish) and drain time
/// (`MSTAT` polled until the migration queue is idle, within a budget
/// that never delays the next scheduled event).
pub fn inject(
    mut admin: Box<dyn Target>,
    plan: &[(Duration, ChurnAction)],
    start: Instant,
    buckets: u32,
) -> Vec<ChurnEvent> {
    let mut events: Vec<ChurnEvent> = Vec::with_capacity(plan.len());
    let mut cursor = 0u32;
    for (i, (at, action)) in plan.iter().enumerate() {
        let elapsed = start.elapsed();
        if *at > elapsed {
            std::thread::sleep(*at - elapsed);
        }
        let stamp = start.elapsed().as_millis() as u64;
        // The drain poll may use at most the gap to the next scheduled
        // event (a oneshot burst must not serialize into kill→drain→kill).
        let drain_budget = match plan.get(i + 1) {
            Some((next_at, _)) => {
                DRAIN_POLL_BUDGET.min((start + *next_at).saturating_duration_since(Instant::now()))
            }
            None => DRAIN_POLL_BUDGET,
        };
        let event = match action {
            ChurnAction::Kill => {
                // Probe bucket ids until one KILL is accepted (a bucket may
                // already be down; the service answers ERR and we move on).
                let mut found = None;
                for _ in 0..buckets.max(1) {
                    let b = cursor % buckets.max(1);
                    cursor = cursor.wrapping_add(1);
                    let t0 = Instant::now();
                    match admin.call(&format!("KILL {b}")) {
                        Ok(r) if r.starts_with("KILLED") => {
                            found = Some((b, r, t0.elapsed()));
                            break;
                        }
                        Ok(_) => continue,
                        Err(e) => {
                            events.push(ChurnEvent {
                                offset_ms: stamp,
                                action: "error",
                                epoch: 0,
                                admin_rtt_ns: 0,
                                drain_ms: None,
                                line: format!("[{stamp}ms] admin connection lost: {e}"),
                            });
                            return events;
                        }
                    }
                }
                match found {
                    Some((b, r, rtt)) => ChurnEvent {
                        offset_ms: stamp,
                        action: "kill",
                        epoch: parse_epoch(&r),
                        admin_rtt_ns: crate::metrics::duration_to_ns(rtt),
                        drain_ms: measure_drain(&mut admin, drain_budget),
                        line: format!("[{stamp}ms] KILL {b} -> {r}"),
                    },
                    None => ChurnEvent {
                        offset_ms: stamp,
                        action: "kill-skipped",
                        epoch: 0,
                        admin_rtt_ns: 0,
                        drain_ms: None,
                        line: format!("[{stamp}ms] KILL skipped: no killable bucket"),
                    },
                }
            }
            ChurnAction::Restore => {
                let t0 = Instant::now();
                match admin.call("ADD") {
                    Ok(r) => ChurnEvent {
                        offset_ms: stamp,
                        action: "add",
                        epoch: parse_epoch(&r),
                        admin_rtt_ns: crate::metrics::duration_to_ns(t0.elapsed()),
                        drain_ms: measure_drain(&mut admin, drain_budget),
                        line: format!("[{stamp}ms] ADD -> {r}"),
                    },
                    Err(e) => {
                        events.push(ChurnEvent {
                            offset_ms: stamp,
                            action: "error",
                            epoch: 0,
                            admin_rtt_ns: 0,
                            drain_ms: None,
                            line: format!("[{stamp}ms] admin connection lost: {e}"),
                        });
                        return events;
                    }
                }
            }
        };
        events.push(event);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_plans_nothing() {
        assert!(ChurnScenario::Stable.plan(Duration::from_secs(1)).is_empty());
    }

    #[test]
    fn oneshot_fires_everything_at_the_midpoint() {
        let plan = ChurnScenario::OneShot { kills: 3 }.plan(Duration::from_secs(2));
        assert_eq!(plan.len(), 3);
        for (at, action) in &plan {
            assert_eq!(*at, Duration::from_secs(1));
            assert_eq!(*action, ChurnAction::Kill);
        }
    }

    #[test]
    fn incremental_spreads_kills_then_restores() {
        let plan = ChurnScenario::Incremental { kills: 4 }.plan(Duration::from_secs(10));
        assert_eq!(plan.len(), 8);
        let kills: Vec<_> =
            plan.iter().filter(|(_, a)| *a == ChurnAction::Kill).map(|(t, _)| *t).collect();
        let restores: Vec<_> =
            plan.iter().filter(|(_, a)| *a == ChurnAction::Restore).map(|(t, _)| *t).collect();
        assert_eq!(kills.len(), 4);
        assert_eq!(restores.len(), 4);
        assert!(kills.windows(2).all(|w| w[0] < w[1]), "kills in order");
        assert!(kills.last().unwrap() < restores.first().unwrap(), "kills before restores");
        assert!(*restores.last().unwrap() < Duration::from_secs(10));
    }

    #[test]
    fn by_name_round_trips() {
        for name in ["stable", "oneshot", "incremental"] {
            assert_eq!(ChurnScenario::by_name(name, 2).unwrap().name(), name);
        }
        assert!(ChurnScenario::by_name("thundering-herd", 2).is_err());
    }

    #[test]
    fn inject_drives_a_live_service() {
        use crate::coordinator::router::Router;
        use crate::coordinator::service::Service;
        let router = Router::new("memento", 6, 60, None).unwrap();
        let svc = Service::new(router.clone());
        let admin = Box::new(super::super::target::InProcTarget::new(svc));
        let plan = vec![
            (Duration::ZERO, ChurnAction::Kill),
            (Duration::ZERO, ChurnAction::Kill),
            (Duration::ZERO, ChurnAction::Restore),
        ];
        let events = inject(admin, &plan, Instant::now(), 6);
        assert_eq!(events.len(), 3, "{events:?}");
        assert!(events[0].line.contains("KILLED"), "{}", events[0].line);
        assert!(events[1].line.contains("KILLED"), "{}", events[1].line);
        assert!(events[2].line.contains("ADDED"), "{}", events[2].line);
        assert_eq!(router.working(), 5);
        // The availability window is measured end to end: the admin rtt
        // is always captured, the epoch is parsed from the response, and
        // the last event (with a real polling budget) sees the drain.
        for e in &events[..2] {
            assert_eq!(e.action, "kill");
            assert!(e.admin_rtt_ns > 0, "{e:?}");
        }
        assert_eq!(events[0].epoch, 1, "{events:?}");
        assert_eq!(events[1].epoch, 2, "{events:?}");
        assert_eq!(events[2].action, "add");
        assert_eq!(events[2].epoch, 3, "{events:?}");
        assert!(events[2].drain_ms.is_some(), "final drain must complete: {events:?}");
    }

    #[test]
    fn epoch_parsing_tolerates_other_responses() {
        assert_eq!(parse_epoch("KILLED node-3 EPOCH 4 SOURCES 1"), 4);
        assert_eq!(parse_epoch("ADDED BUCKET 2 NODE node-2 EPOCH 7 SOURCES 3"), 7);
        assert_eq!(parse_epoch("KILLED node-3 MOVED 42"), 0, "legacy response shape");
        assert_eq!(parse_epoch("ERR whatever"), 0);
    }
}
