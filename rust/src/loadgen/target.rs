//! Load targets: where generated traffic goes.
//!
//! A [`Target`] maps one protocol line to one response line — the same
//! contract as [`crate::coordinator::service::Service::handle`] and the
//! TCP line protocol, so the generator can drive either interchangeably:
//!
//! * [`InProcTarget`] calls the service directly (isolates engine +
//!   storage cost from protocol overhead);
//! * [`TcpTarget`] goes through a real socket to a live
//!   [`crate::netserver`] front-end (measures the whole stack).
//!
//! Each worker thread gets its own target from a [`TargetFactory`], so
//! TCP workers hold independent connections and in-process workers share
//! the service through its own internal synchronization.

use crate::coordinator::service::Service;
use crate::netserver::Client;
use std::net::SocketAddr;
use std::sync::Arc;

/// One request line in, one response line out. Implementations must be
/// [`Send`] — every worker thread owns one target exclusively.
pub trait Target: Send {
    /// Issue one request and wait for its response.
    fn call(&mut self, line: &str) -> std::io::Result<String>;

    /// Issue a batch of requests, returning one response per request.
    /// The default loops over [`Target::call`]; transports that can
    /// pipeline (TCP) override it to collapse N round trips into one.
    fn call_many(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        lines.iter().map(|l| self.call(l)).collect()
    }
}

/// Creates one independent [`Target`] per worker thread (plus one for the
/// churn injector and one for preloading).
pub type TargetFactory = Arc<dyn Fn() -> std::io::Result<Box<dyn Target>> + Send + Sync>;

/// Drives an in-process [`Service`] without any protocol framing.
pub struct InProcTarget {
    svc: Arc<Service>,
}

impl InProcTarget {
    /// A target over a shared service handle.
    pub fn new(svc: Arc<Service>) -> Self {
        Self { svc }
    }
}

impl Target for InProcTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        Ok(self.svc.handle(line))
    }
}

/// Drives a live TCP front-end over one pipelined connection.
pub struct TcpTarget {
    client: Client,
}

impl TcpTarget {
    /// Connect to a running server.
    pub fn connect(addr: &SocketAddr) -> std::io::Result<Self> {
        Ok(Self { client: Client::connect(addr)? })
    }
}

impl Target for TcpTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        self.client.request(line)
    }

    fn call_many(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        self.client.request_pipelined(lines)
    }
}

/// Factory producing in-process targets over one shared service.
pub fn inproc_factory(svc: Arc<Service>) -> TargetFactory {
    Arc::new(move || Ok(Box::new(InProcTarget::new(svc.clone())) as Box<dyn Target>))
}

/// Factory producing one TCP connection per worker.
pub fn tcp_factory(addr: SocketAddr) -> TargetFactory {
    Arc::new(move || TcpTarget::connect(&addr).map(|t| Box::new(t) as Box<dyn Target>))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;

    #[test]
    fn inproc_target_round_trips() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let factory = inproc_factory(svc);
        let mut t = factory().unwrap();
        assert!(t.call("PUT 7 hello").unwrap().starts_with("OK"));
        assert!(t.call("GET 7").unwrap().contains("hello"));
    }

    #[test]
    fn tcp_target_round_trips() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 8).unwrap();
        let factory = tcp_factory(server.addr());
        let mut t = factory().unwrap();
        assert!(t.call("PUT 9 world").unwrap().starts_with("OK"));
        assert!(t.call("GET 9").unwrap().contains("world"));
        drop(t);
        server.shutdown();
    }

    #[test]
    fn call_many_matches_sequential_calls_on_both_transports() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 8).unwrap();
        let lines: Vec<String> = (0..50)
            .map(|i| if i % 2 == 0 { format!("PUT k{i} v{i}") } else { format!("LOOKUP k{i}") })
            .collect();
        let mut inproc = inproc_factory(svc.clone())().unwrap();
        let mut tcp = tcp_factory(server.addr())().unwrap();
        let a = inproc.call_many(&lines).unwrap();
        let b = tcp.call_many(&lines).unwrap();
        assert_eq!(a.len(), 50);
        assert_eq!(a, b, "pipelined TCP must answer in order with identical responses");
        drop(tcp);
        server.shutdown();
    }
}
