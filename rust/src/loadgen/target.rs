//! Load targets: where generated traffic goes.
//!
//! A [`Target`] maps one protocol line to one response line — the same
//! contract as [`crate::coordinator::service::Service::handle`] and the
//! TCP line protocol, so the generator can drive either interchangeably:
//!
//! * [`InProcTarget`] calls the service directly (isolates engine +
//!   storage cost from protocol overhead);
//! * [`TcpTarget`] goes through a real socket to a live
//!   [`crate::netserver`] front-end (measures the whole stack), on
//!   either wire protocol — text lines or binary frames
//!   ([`tcp_binary_factory`]); every line is parsed into a typed
//!   [`Request`] and the typed [`Response`] rendered back, so the
//!   generator's line-oriented bookkeeping (including `ERR `-prefix
//!   error counting) is protocol-agnostic;
//! * [`FanoutTarget`] holds many connections per worker and
//!   round-robins requests across them — the connection-scaling cells
//!   (1k+ open sockets) come from here, not from 1k threads.
//!
//! Each worker thread gets its own target from a [`TargetFactory`], so
//! TCP workers hold independent connections and in-process workers share
//! the service through its own internal synchronization.

use crate::coordinator::service::Service;
use crate::netserver::{Client, ClientError};
use crate::proto::Request;
use std::net::SocketAddr;
use std::sync::Arc;

/// One request line in, one response line out. Implementations must be
/// [`Send`] — every worker thread owns one target exclusively.
pub trait Target: Send {
    /// Issue one request and wait for its response.
    fn call(&mut self, line: &str) -> std::io::Result<String>;

    /// Issue a batch of requests, returning one response per request.
    /// The default loops over [`Target::call`]; transports that can
    /// pipeline (TCP) override it to collapse N round trips into one.
    fn call_many(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        lines.iter().map(|l| self.call(l)).collect()
    }
}

/// Creates one independent [`Target`] per worker thread (plus one for the
/// churn injector and one for preloading).
pub type TargetFactory = Arc<dyn Fn() -> std::io::Result<Box<dyn Target>> + Send + Sync>;

/// Drives an in-process [`Service`] without any protocol framing.
pub struct InProcTarget {
    svc: Arc<Service>,
}

impl InProcTarget {
    /// A target over a shared service handle.
    pub fn new(svc: Arc<Service>) -> Self {
        Self { svc }
    }
}

impl Target for InProcTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        Ok(self.svc.handle(line))
    }
}

/// Issue one line over a client on either protocol: parse → typed call
/// → render. Protocol errors (parse rejects and server `ERR` frames /
/// lines) come back as `ERR <CODE> <msg>` lines so the generator counts
/// them uniformly; only transport failures surface as `io::Error`.
/// (This used to exist only for binary mode while text mode rode the
/// raw-line `Client::request*` shims; those shims were removed —
/// DESIGN.md §13 — and both modes share the typed path.)
fn call_typed(client: &mut Client, line: &str) -> std::io::Result<String> {
    let req = match Request::parse_text(line) {
        Ok(req) => req,
        Err(e) => return Ok(e.render_text()),
    };
    match client.call(&req) {
        Ok(resp) => Ok(resp.render_text()),
        Err(ClientError::Proto(e)) => Ok(e.render_text()),
        Err(ClientError::Io(e)) => Err(e),
    }
}

/// Drives a live TCP front-end over one pipelined connection, on
/// either wire protocol (the mode is fixed at connect time; the typed
/// client API covers both).
pub struct TcpTarget {
    client: Client,
}

impl TcpTarget {
    /// Connect to a running server on the text protocol.
    pub fn connect(addr: &SocketAddr) -> std::io::Result<Self> {
        Ok(Self { client: Client::connect(addr)? })
    }

    /// Connect to a running server on the binary frame protocol.
    pub fn connect_binary(addr: &SocketAddr) -> std::io::Result<Self> {
        Ok(Self { client: Client::connect_binary(addr)? })
    }
}

impl Target for TcpTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        call_typed(&mut self.client, line)
    }

    fn call_many(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        // Parse every line up front; unparseable slots answer locally
        // and only the typed requests ride the pipelined batch, keeping
        // responses aligned with their request index.
        let mut out: Vec<Option<String>> = Vec::with_capacity(lines.len());
        let mut reqs = Vec::with_capacity(lines.len());
        for line in lines {
            match Request::parse_text(line) {
                Ok(req) => {
                    out.push(None);
                    reqs.push(req);
                }
                Err(e) => out.push(Some(e.render_text())),
            }
        }
        let mut answers = self.client.call_many(&reqs)?.into_iter();
        Ok(out
            .into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| match answers.next() {
                    Some(Ok(resp)) => resp.render_text(),
                    Some(Err(e)) => e.render_text(),
                    None => {
                        crate::proto::ProtoError::unavailable("pipelined response missing")
                            .render_text()
                    }
                })
            })
            .collect())
    }
}

/// Round-robins requests across many connections from one worker
/// thread — the connection-count scaling cells. Each call uses the
/// next connection, so N in-flight workers keep `conns × workers`
/// sockets open against the server with a bounded thread count.
pub struct FanoutTarget {
    conns: Vec<TcpTarget>,
    next: usize,
}

impl FanoutTarget {
    /// Open `conns` connections to a running server.
    pub fn connect(addr: &SocketAddr, conns: usize, binary: bool) -> std::io::Result<Self> {
        let conns = conns.max(1);
        let mut v = Vec::with_capacity(conns);
        for _ in 0..conns {
            v.push(if binary {
                TcpTarget::connect_binary(addr)?
            } else {
                TcpTarget::connect(addr)?
            });
        }
        Ok(Self { conns: v, next: 0 })
    }
}

impl Target for FanoutTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        let i = self.next;
        self.next = (self.next + 1) % self.conns.len();
        self.conns[i].call(line)
    }
}

/// Factory producing in-process targets over one shared service.
pub fn inproc_factory(svc: Arc<Service>) -> TargetFactory {
    Arc::new(move || Ok(Box::new(InProcTarget::new(svc.clone())) as Box<dyn Target>))
}

/// Factory producing one text-protocol TCP connection per worker.
pub fn tcp_factory(addr: SocketAddr) -> TargetFactory {
    Arc::new(move || TcpTarget::connect(&addr).map(|t| Box::new(t) as Box<dyn Target>))
}

/// Factory producing one binary-protocol TCP connection per worker.
pub fn tcp_binary_factory(addr: SocketAddr) -> TargetFactory {
    Arc::new(move || TcpTarget::connect_binary(&addr).map(|t| Box::new(t) as Box<dyn Target>))
}

/// Factory producing `conns` connections per worker, round-robined.
pub fn fanout_factory(addr: SocketAddr, conns: usize, binary: bool) -> TargetFactory {
    Arc::new(move || {
        FanoutTarget::connect(&addr, conns, binary).map(|t| Box::new(t) as Box<dyn Target>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;

    #[test]
    fn inproc_target_round_trips() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let factory = inproc_factory(svc);
        let mut t = factory().unwrap();
        assert!(t.call("PUT 7 hello").unwrap().starts_with("OK"));
        assert!(t.call("GET 7").unwrap().contains("hello"));
    }

    #[test]
    fn tcp_target_round_trips() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 8).unwrap();
        let factory = tcp_factory(server.addr());
        let mut t = factory().unwrap();
        assert!(t.call("PUT 9 world").unwrap().starts_with("OK"));
        assert!(t.call("GET 9").unwrap().contains("world"));
        drop(t);
        server.shutdown();
    }

    #[test]
    fn call_many_matches_sequential_calls_on_both_transports() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 8).unwrap();
        let lines: Vec<String> = (0..50)
            .map(|i| if i % 2 == 0 { format!("PUT k{i} v{i}") } else { format!("LOOKUP k{i}") })
            .collect();
        let mut inproc = inproc_factory(svc.clone())().unwrap();
        let mut tcp = tcp_factory(server.addr())().unwrap();
        let a = inproc.call_many(&lines).unwrap();
        let b = tcp.call_many(&lines).unwrap();
        assert_eq!(a.len(), 50);
        assert_eq!(a, b, "pipelined TCP must answer in order with identical responses");
        drop(tcp);
        server.shutdown();
    }

    #[test]
    fn binary_target_matches_text_target() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 8).unwrap();
        let mut text = tcp_factory(server.addr())().unwrap();
        let mut bin = tcp_binary_factory(server.addr())().unwrap();
        for line in ["PUT k1 v1", "GET k1", "LOOKUP k1", "GET nope", "FROB"] {
            assert_eq!(
                text.call(line).unwrap(),
                bin.call(line).unwrap(),
                "text and binary targets must agree on {line:?}"
            );
        }
        let lines: Vec<String> = (0..40).map(|i| format!("LOOKUP key{i}")).collect();
        assert_eq!(text.call_many(&lines).unwrap(), bin.call_many(&lines).unwrap());
        drop((text, bin));
        server.shutdown();
    }

    #[test]
    fn fanout_target_opens_many_connections() {
        let router = Router::new("memento", 4, 40, None).unwrap();
        let svc = Service::new(router);
        let server = svc.serve("127.0.0.1:0", 64).unwrap();
        let mut t = fanout_factory(server.addr(), 8, true)().unwrap();
        for i in 0..32 {
            assert!(t.call(&format!("LOOKUP key{i}")).unwrap().starts_with("BUCKET "));
        }
        assert!(
            server.live_connections() >= 8,
            "fanout target should hold all its connections open"
        );
        drop(t);
        server.shutdown();
    }
}
