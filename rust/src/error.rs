//! `error` — the crate-wide error type (a dependency-free `anyhow`
//! stand-in for the offline crate set).
//!
//! The [`Error`] enum carries context the way operators need to read it:
//! every layer can wrap a lower failure with one line of "what was being
//! attempted" via [`Context::context`], and [`std::fmt::Display`] renders
//! the chain outermost-first (`load artifacts: parse foo.hlo.txt: …`).
//!
//! Construction idioms (mirroring `anyhow`):
//!
//! ```
//! use memento::error::{Context, Result};
//!
//! fn parse_port(s: &str) -> memento::Result<u16> {
//!     if s.is_empty() {
//!         memento::bail!("empty port");
//!     }
//!     s.parse::<u16>().map_err(|_| memento::err!("bad port '{s}'"))
//! }
//!
//! let e: Result<u16> = parse_port("x").context("reading config");
//! assert_eq!(e.unwrap_err().to_string(), "reading config: bad port 'x'");
//! ```

use crate::algorithms::AlgoError;
use std::fmt;

/// Crate-wide result alias; the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// The crate error: a small context-carrying enum.
///
/// Variants are coarse on purpose — callers match on *kind* (I/O vs
/// algorithm rejection vs config) and render the rest; fine-grained
/// typed errors stay local to their layer (e.g.
/// [`crate::algorithms::AlgoError`]).
#[derive(Debug)]
pub enum Error {
    /// A free-form failure message (what [`crate::err!`] produces).
    Msg(String),
    /// An I/O failure, tagged with what was being attempted.
    Io {
        /// What the crate was doing when the I/O failed.
        context: String,
        /// The underlying I/O error.
        source: std::io::Error,
    },
    /// A cluster-resize rejection bubbled up from an algorithm.
    Algo(AlgoError),
    /// A configuration failure (TOML parse or schema validation).
    Config(String),
    /// A lower error wrapped with one line of context
    /// ([`Context::context`]).
    Context {
        /// The added context line.
        context: String,
        /// The wrapped error.
        source: Box<Error>,
    },
}

impl Error {
    /// Build a free-form [`Error::Msg`] (prefer the [`crate::err!`] macro,
    /// which accepts a format string).
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }

    /// Wrap `self` with a context line; `Display` renders
    /// `"{context}: {self}"`.
    pub fn wrap(self, context: impl Into<String>) -> Self {
        Error::Context { context: context.into(), source: Box::new(self) }
    }

    /// The innermost error message (the chain's root cause).
    pub fn root_cause(&self) -> String {
        match self {
            Error::Context { source, .. } => source.root_cause(),
            Error::Io { source, .. } => source.to_string(),
            Error::Algo(e) => e.to_string(),
            Error::Msg(m) | Error::Config(m) => m.clone(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Msg(m) => f.write_str(m),
            Error::Io { context, source } => write!(f, "{context}: {source}"),
            Error::Algo(e) => write!(f, "{e}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Context { context, source } => write!(f, "{context}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            Error::Algo(e) => Some(e),
            Error::Context { source, .. } => Some(source.as_ref()),
            Error::Msg(_) | Error::Config(_) => None,
        }
    }
}

impl From<AlgoError> for Error {
    fn from(e: AlgoError) -> Self {
        Error::Algo(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io { context: "I/O".into(), source: e }
    }
}

impl From<String> for Error {
    fn from(m: String) -> Self {
        Error::Msg(m)
    }
}

impl From<&str> for Error {
    fn from(m: &str) -> Self {
        Error::Msg(m.to_string())
    }
}

/// `anyhow::Context`-style extension: attach a context line to the error
/// of a `Result`, or turn an `Option::None` into a contextual error.
pub trait Context<T> {
    /// Wrap the failure with a fixed context line.
    fn context(self, msg: impl Into<String>) -> Result<T>;

    /// Wrap the failure with a lazily built context line (use when the
    /// message formats values on the hot path).
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::Msg(msg.into()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::Msg(f()))
    }
}

/// Construct an [`Error`] from a format string (an `anyhow!` stand-in).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::Msg(::std::format!($($arg)*))
    };
}

/// Early-return `Err(err!(…))` from the enclosing function (a `bail!`
/// stand-in).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into())
    }

    #[test]
    fn err_macro_formats() {
        let e = crate::err!("bucket {} of {total}", 3, total = 10);
        assert_eq!(e.to_string(), "bucket 3 of 10");
        assert!(matches!(e, Error::Msg(_)));
    }

    #[test]
    fn bail_macro_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("asked to fail");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "asked to fail");
    }

    #[test]
    fn context_wraps_outermost_first() {
        let e = io_fail().context("loading artifacts").unwrap_err();
        let rendered = e.to_string();
        assert!(rendered.starts_with("loading artifacts:"), "{rendered}");
        assert!(rendered.contains("gone"), "{rendered}");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn with_context_is_lazy_on_success() {
        let mut called = false;
        let r: Result<u32> = Ok(1u32);
        let v = r
            .with_context(|| {
                called = true;
                "never".into()
            })
            .unwrap();
        assert_eq!(v, 1);
        assert!(!called, "context closure must not run on Ok");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing variant").unwrap_err();
        assert_eq!(e.to_string(), "missing variant");
        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn algo_errors_convert_and_chain() {
        let e: Error = AlgoError::NotWorking(9).into();
        assert!(e.to_string().contains("bucket 9"));
        let wrapped = e.wrap("failing node");
        assert_eq!(wrapped.to_string(), "failing node: bucket 9 is not working");
        // The std error chain is preserved for `source()` walkers.
        let mut depth = 0;
        let mut cur: &dyn std::error::Error = &wrapped;
        while let Some(next) = cur.source() {
            depth += 1;
            cur = next;
        }
        assert_eq!(depth, 2, "Context -> Algo -> AlgoError");
    }

    #[test]
    fn nested_context_renders_as_a_chain() {
        let e = io_fail()
            .context("parse memento_b1024_n4096.hlo.txt")
            .context("load artifacts")
            .unwrap_err();
        assert_eq!(
            e.to_string(),
            "load artifacts: parse memento_b1024_n4096.hlo.txt: I/O: gone"
        );
    }

    #[test]
    fn string_conversions() {
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
        let e: Error = String::from("owned").into();
        assert_eq!(e.to_string(), "owned");
        let e = Error::Config("bad key".into());
        assert_eq!(e.to_string(), "config: bad key");
    }
}
