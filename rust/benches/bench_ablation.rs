//! Ablations of Memento's design choices (DESIGN.md §4):
//!
//! 1. **Inner-loop guard** (`u ≥ w_b`, Alg. 4 line 7): the paper's
//!    Fig. 13-16 argues this guard is what preserves balance. We measure
//!    the max per-bucket deviation with and without it.
//! 2. **Rehash function** (Note III.1): Memento assumes a uniform hash for
//!    the Alg. 4 line-5 rehash. We sweep SplitMix64 (default), xxHash64,
//!    Murmur3-fmix64-alike and the deliberately weak FNV-1a, measuring
//!    both balance and lookup latency.
//! 3. **Replacement-map load factor** is covered indirectly: ReplMap grows
//!    at 3/4 occupancy; we report lookup latency at several removal levels
//!    to show probe-length stability.

use memento::algorithms::{ConsistentHasher, Memento, RemovalOrder};
use memento::benchkit::report::Table;
use memento::benchkit::{self, BenchConfig};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::hashing::{self, Hasher64};
use memento::simulator::{audit, scenario};
use std::sync::Arc;

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

fn main() {
    ablation_inner_guard();
    ablation_rehash_function();
    ablation_replmap_under_churn();
    ablation_bounded_load();
}

/// §X bounded loads: the balance/placement-cost trade as c varies.
fn ablation_bounded_load() {
    use memento::algorithms::bounded::BoundedLoad;
    let mut t = Table::new(
        "Ablation — bounded loads (CHBL over memento, w=100, k=300 keys)",
        &["c", "peak_to_avg", "unbounded_peak_to_avg", "assign_ns"],
    );
    let ks = keys(300, 0x6F);
    // Unbounded baseline.
    let m = Memento::new(100);
    let mut loads = std::collections::HashMap::<u32, u64>::new();
    for &k in &ks {
        *loads.entry(m.lookup(k)).or_default() += 1;
    }
    let unbounded = *loads.values().max().unwrap() as f64 * 100.0 / ks.len() as f64;
    let cfg = BenchConfig::quick();
    for c in [1.05f64, 1.25, 1.5, 2.0] {
        let mut bl = BoundedLoad::new(Memento::new(100), c);
        for &k in &ks {
            bl.assign(k);
        }
        let peak = bl.peak_to_avg();
        // Assignment walk cost (fresh placements, steady churn).
        let mut i = 0usize;
        let stats = benchkit::bench(&format!("assign c={c}"), &cfg, || {
            let k = ks[i % ks.len()];
            bl.release(k);
            benchkit::black_box(bl.assign(k));
            i += 1;
        });
        t.push_row(vec![
            format!("{c:.2}"),
            format!("{peak:.3}"),
            format!("{unbounded:.3}"),
            format!("{:.0}", stats.median_ns),
        ]);
    }
    t.emit("ablation_bounded_load");
}

/// Fig. 13-16 ablation: balance with vs without the inner guard.
fn ablation_inner_guard() {
    let mut t = Table::new(
        "Ablation — inner-loop guard (u ≥ w_b): balance impact",
        &["w", "removed", "guarded_maxdev", "unguarded_maxdev", "guard_wins"],
    );
    let ks = keys(200_000, 0x6A);
    let mut rng = Xoshiro256::new(0x6B);
    for (w, removals) in [(6usize, 3usize), (50, 30), (500, 300), (2000, 1300)] {
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let guarded = audit::balance(&m, &ks).max_deviation;
        // Unguarded variant over the same state.
        let working = m.working_buckets();
        let mut counts = std::collections::HashMap::<u32, u64>::new();
        for &k in &ks {
            *counts.entry(m.lookup_unguarded(k)).or_default() += 1;
        }
        let ideal = ks.len() as f64 / working.len() as f64;
        let unguarded = working
            .iter()
            .map(|b| (counts.get(b).copied().unwrap_or(0) as f64 - ideal).abs() / ideal)
            .fold(0.0f64, f64::max);
        t.push_row(vec![
            w.to_string(),
            removals.to_string(),
            format!("{guarded:.4}"),
            format!("{unguarded:.4}"),
            (guarded < unguarded).to_string(),
        ]);
    }
    t.emit("ablation_inner_guard");
}

/// Note III.1 ablation: the rehash function.
fn ablation_rehash_function() {
    let mut t = Table::new(
        "Ablation — rehash function (Note III.1)",
        &["hash", "maxdev", "chi2_uniform", "lookup_ns"],
    );
    let ks = keys(150_000, 0x6C);
    let cfg = BenchConfig::quick();
    let hashers: Vec<(&str, Option<Arc<dyn Hasher64>>)> = vec![
        ("splitmix64(default)", None),
        ("xxhash64", Some(Arc::new(hashing::xxhash::XxHash64))),
        ("murmur3", Some(Arc::new(hashing::murmur3::Murmur3_128))),
        ("fnv1a(weak)", Some(Arc::new(hashing::fnv::Fnv1a64))),
    ];
    for (label, hasher) in hashers {
        let mut m = match &hasher {
            None => Memento::new(1000),
            Some(h) => Memento::with_hasher(1000, h.clone()),
        };
        let mut rng = Xoshiro256::new(0x6D);
        scenario::apply_removals(&mut m, 650, RemovalOrder::Random, &mut rng);
        let rep = audit::balance(&m, &ks);
        let mut i = 0usize;
        let stats = benchkit::bench(label, &cfg, || {
            benchkit::black_box(m.lookup(benchkit::black_box(ks[i])));
            i = (i + 1) % ks.len();
        });
        t.push_row(vec![
            label.into(),
            format!("{:.4}", rep.max_deviation),
            rep.is_uniform(6.0).to_string(),
            format!("{:.1}", stats.median_ns),
        ]);
    }
    t.emit("ablation_rehash");
}

/// ReplMap probe-length stability: lookup latency as R fills.
fn ablation_replmap_under_churn() {
    let mut t = Table::new(
        "Ablation — ReplMap occupancy vs lookup latency",
        &["w", "removed", "r_bytes", "lookup_ns"],
    );
    let cfg = BenchConfig::quick();
    let ks = keys(100_000, 0x6E);
    for removals in [0usize, 1000, 5000, 20_000, 50_000] {
        let mut m = Memento::new(100_000);
        let mut rng = Xoshiro256::new(3);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let mut i = 0usize;
        let stats = benchkit::bench(&format!("churn{removals}"), &cfg, || {
            benchkit::black_box(m.lookup(benchkit::black_box(ks[i])));
            i = (i + 1) % ks.len();
        });
        t.push_row(vec![
            "100000".into(),
            removals.to_string(),
            m.state_bytes().to_string(),
            format!("{:.1}", stats.median_ns),
        ]);
    }
    t.emit("ablation_replmap");
}
