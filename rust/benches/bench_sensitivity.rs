//! Figs. 27-32 — §VIII-E sensitivity to the a/w over-provisioning ratio
//! (5/10/20/50/100) at 0%, 20% and 65% removals: lookup time and memory.
//!
//! Paper shape: Dx lookup grows linearly with the ratio, Anchor
//! logarithmically; both algorithms' memory grows linearly; Memento is a
//! flat baseline (it has no capacity bound at all).

use memento::simulator::{figures, Scale, ScenarioConfig};

fn main() {
    let scale = Scale::from_env();
    let cfg = ScenarioConfig::default();
    figures::fig_27_32_sensitivity(scale, &cfg).emit("fig_27_32_sensitivity");
}
