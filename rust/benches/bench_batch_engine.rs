//! Batch-engine throughput: the L1/L2 contribution measured end-to-end.
//!
//! Compares keys/s of the scalar rust Memento lookup against the PJRT
//! batched engine at several batch sizes and removal levels, plus the
//! dynamic batcher's end-to-end latency. Run `make artifacts` first —
//! without artifacts only the scalar rows are printed.

use memento::algorithms::{ConsistentHasher, Memento, RemovalOrder};
use memento::benchkit::report::Table;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::runtime::{ArtifactCatalog, Engine};
use memento::simulator::scenario;
use std::path::Path;
use std::time::Instant;

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

fn main() {
    let dir = Path::new("artifacts");
    let have_engine = !ArtifactCatalog::scan(dir).is_empty();
    let engine = if have_engine {
        match Engine::load(dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("engine load failed: {err}");
                None
            }
        }
    } else {
        eprintln!("[note] artifacts/ missing — scalar rows only (`make artifacts`)");
        None
    };

    let mut t = Table::new(
        "Batch engine vs scalar lookup throughput",
        &["path", "w", "removed", "batch", "keys_per_sec", "ns_per_key"],
    );

    let mut rng = Xoshiro256::new(0xB47C);
    for (w, removals) in [(10_000usize, 0usize), (10_000, 2_000), (100_000, 30_000)] {
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);

        // Scalar baseline.
        let ks = keys(1 << 16, w as u64);
        let t0 = Instant::now();
        let mut acc = 0u32;
        for &k in &ks {
            acc = acc.wrapping_add(m.lookup(k));
        }
        std::hint::black_box(acc);
        let scalar_ns = t0.elapsed().as_nanos() as f64 / ks.len() as f64;
        t.push_row(vec![
            "scalar".into(),
            w.to_string(),
            removals.to_string(),
            "1".into(),
            format!("{:.0}", 1e9 / scalar_ns),
            format!("{scalar_ns:.1}"),
        ]);

        // Device path at growing batch sizes.
        if let Some(engine) = &engine {
            for batch in [1usize << 12, 1 << 14, 1 << 16] {
                let ks = keys(batch, w as u64 + 1);
                // Warm once (compile cache, first-dispatch cost).
                let _ = engine.memento_lookup(&m, &ks);
                let reps = (1 << 18) / batch;
                let t0 = Instant::now();
                for _ in 0..reps.max(1) {
                    std::hint::black_box(engine.memento_lookup(&m, &ks).unwrap());
                }
                let ns = t0.elapsed().as_nanos() as f64 / (reps.max(1) * batch) as f64;
                t.push_row(vec![
                    "pjrt-engine".into(),
                    w.to_string(),
                    removals.to_string(),
                    batch.to_string(),
                    format!("{:.0}", 1e9 / ns),
                    format!("{ns:.1}"),
                ]);
            }
        }
    }
    t.emit("batch_engine_throughput");

    if let Some(engine) = &engine {
        println!(
            "engine fallback rate: {:.5} (device={} fallback={})",
            engine.stats.fallback_rate(),
            engine.stats.device_keys.load(std::sync::atomic::Ordering::Relaxed),
            engine.stats.fallback_keys.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
}
