//! Batch-engine throughput: the batched-lookup contribution measured
//! end-to-end.
//!
//! Compares keys/s of the scalar rust Memento lookup against the batched
//! engine at several batch sizes and removal levels, on both the
//! convenience path (per-call snapshot build) and the steady-state path
//! (per-epoch snapshot reuse — what the router dispatches). Runs against
//! whatever backend `Engine::load` selects: the pure-Rust `rust-batch`
//! backend by default, or PJRT with `--features pjrt` + `make artifacts`.

use memento::algorithms::{ConsistentHasher, Memento, RemovalOrder};
use memento::benchkit::report::Table;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::runtime::{Engine, EngineSnapshot};
use memento::simulator::scenario;
use std::path::Path;
use std::time::Instant;

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

fn main() {
    let engine = Engine::load(Path::new("artifacts")).expect("engine backend");
    let platform = engine.platform();
    println!("engine backend: {platform}");

    let mut t = Table::new(
        "Batch engine vs scalar lookup throughput",
        &["path", "w", "removed", "batch", "keys_per_sec", "ns_per_key"],
    );

    let mut rng = Xoshiro256::new(0xB47C);
    for (w, removals) in [(10_000usize, 0usize), (10_000, 2_000), (100_000, 30_000)] {
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);

        // Scalar baseline.
        let ks = keys(1 << 16, w as u64);
        let t0 = Instant::now();
        let mut acc = 0u32;
        for &k in &ks {
            acc = acc.wrapping_add(m.lookup(k));
        }
        std::hint::black_box(acc);
        let scalar_ns = t0.elapsed().as_nanos() as f64 / ks.len() as f64;
        t.push_row(vec![
            "scalar".into(),
            w.to_string(),
            removals.to_string(),
            "1".into(),
            format!("{:.0}", 1e9 / scalar_ns),
            format!("{scalar_ns:.1}"),
        ]);

        // Steady-state engine path: the per-epoch snapshot is built once
        // (as the router does) and reused across dispatches.
        let table = engine.table_size_for(m.size()).expect("table size");
        let snap = EngineSnapshot::new(m.clone(), table);
        for batch in [1usize << 12, 1 << 14, 1 << 16] {
            let ks = keys(batch, w as u64 + 1);
            // Warm once (first-dispatch cost).
            let _ = engine.memento_lookup_snapshot(&snap, &ks);
            let reps = ((1 << 18) / batch).max(1);
            let t0 = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(engine.memento_lookup_snapshot(&snap, &ks).unwrap());
            }
            let ns = t0.elapsed().as_nanos() as f64 / (reps * batch) as f64;
            t.push_row(vec![
                "engine-snap".into(),
                w.to_string(),
                removals.to_string(),
                batch.to_string(),
                format!("{:.0}", 1e9 / ns),
                format!("{ns:.1}"),
            ]);
        }

        // Convenience path (clones + freezes the snapshot per call):
        // measures the cost the steady path avoids.
        let batch = 1usize << 14;
        let ks = keys(batch, w as u64 + 2);
        let _ = engine.memento_lookup(&m, &ks);
        let reps = ((1 << 17) / batch).max(1);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(engine.memento_lookup(&m, &ks).unwrap());
        }
        let ns = t0.elapsed().as_nanos() as f64 / (reps * batch) as f64;
        t.push_row(vec![
            "engine-oneshot".into(),
            w.to_string(),
            removals.to_string(),
            batch.to_string(),
            format!("{:.0}", 1e9 / ns),
            format!("{ns:.1}"),
        ]);
    }
    t.emit("batch_engine_throughput");

    println!(
        "engine fallback rate: {:.5} (device={} fallback={} dispatches={})",
        engine.stats.fallback_rate(),
        engine.stats.device_keys.load(std::sync::atomic::Ordering::Relaxed),
        engine.stats.fallback_keys.load(std::sync::atomic::Ordering::Relaxed),
        engine.stats.dispatches.load(std::sync::atomic::Ordering::Relaxed),
    );
}
