//! Figs. 17 + 18 — stable scenario: lookup time and memory usage vs
//! cluster size (10 … 10⁶ paper-scale; `MEMENTO_BENCH_SCALE=full`).
//!
//! Paper shape to reproduce: Memento ≈ Jump on lookups, both clearly
//! faster than Anchor and Dx; memory Jump ≤ Memento ≪ Dx < Anchor.

use memento::simulator::{figures, Scale, ScenarioConfig};

fn main() {
    let scale = Scale::from_env();
    let cfg = ScenarioConfig::default();
    let t = figures::fig_17_18_stable(scale, &cfg);
    t.emit("fig_17_18_stable");
    let findings = figures::check_stable_shape(&t);
    if findings.is_empty() {
        println!("shape check: OK (memento ≤ dx on lookup and memory at every size)");
    } else {
        for f in findings {
            println!("shape check: {f}");
        }
    }
}
