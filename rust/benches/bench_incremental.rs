//! Figs. 23-26 — incremental removals (10%…90%) from a large cluster,
//! both orders: lookup time (23/24) and memory usage (25/26).
//!
//! Paper shape: best case, Dx is the clear worst performer and
//! Memento ≈ Jump; worst case, Anchor is slowest until ~65% removals,
//! after which Memento and Dx degrade past it (the crossover the paper
//! calls out in §VIII-D).

use memento::simulator::{figures, Scale, ScenarioConfig};

fn main() {
    let scale = Scale::from_env();
    let cfg = ScenarioConfig::default();
    let t = figures::fig_23_26_incremental(scale, &cfg);
    t.emit("fig_23_26_incremental");

    // Crossover report: the *persistent* point past which memento stays
    // behind anchor in the worst case (single-cell comparisons at low
    // fractions sit within timing noise — the two are nearly equal there).
    let mut rows: Vec<(String, f64, f64)> = Vec::new(); // (algo, frac, ns)
    for r in &t.rows {
        if r[4] == "worst(random)" {
            rows.push((r[0].clone(), r[3].parse().unwrap(), r[5].parse().unwrap()));
        }
    }
    let find = |name: &str, frac: f64| {
        rows.iter()
            .find(|(a, f, _)| a == name && (f - frac).abs() < 1e-9)
            .map(|(_, _, ns)| *ns)
    };
    let crossover = figures::INCREMENTAL_FRACS
        .iter()
        .rev()
        .take_while(|&&frac| match (find("memento", frac), find("anchor", frac)) {
            (Some(m), Some(a)) => m > a,
            _ => false,
        })
        .last()
        .copied();
    match crossover {
        Some(f) => println!(
            "crossover: memento persistently behind anchor from {:.0}% removals on (paper: ~65%)",
            f * 100.0
        ),
        None => println!("crossover: memento stayed ahead of anchor through 90% removals"),
    }
}
