//! Table I — empirical validation of the asymptotic complexity claims.
//!
//! For each algorithm we measure *iteration counts* (not wall time) via
//! `lookup_traced` and fit them against the claimed growth laws:
//!
//! | algo    | lookup claim                 | empirical column            |
//! |---------|------------------------------|-----------------------------|
//! | memento | O(ln n + ln²(n/w))           | jump steps + outer·inner    |
//! | jump    | O(ln w)                      | jump steps                  |
//! | anchor  | O(ln²(a/w))                  | outer·inner                 |
//! | dx      | O(a/w)                       | probes                      |
//!
//! Memory columns report exact `state_bytes()` against Θ(r) / Θ(1) / Θ(a).

use memento::algorithms::{ConsistentHasher, RemovalOrder};
use memento::benchkit::report::Table;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::simulator::scenario::{self, ScenarioConfig};

fn mean_iters(algo: &dyn ConsistentHasher, trials: usize, seed: u64) -> (f64, f64, f64) {
    let mut rng = Xoshiro256::new(seed);
    let (mut js, mut outer, mut inner) = (0u64, 0u64, 0u64);
    for _ in 0..trials {
        let t = algo.lookup_traced(rng.next_u64());
        js += t.jump_steps as u64;
        outer += t.outer_iters as u64;
        inner += t.inner_iters as u64;
    }
    let n = trials as f64;
    (js as f64 / n, outer as f64 / n, inner as f64 / n)
}

fn main() {
    let cfg = ScenarioConfig::default();
    let trials = 30_000;

    // --- lookup-iteration laws at varying (w, removal fraction) ---------
    let mut t = Table::new(
        "Table I — lookup iteration laws (measured vs bound)",
        &[
            "algo", "w", "removed%", "jump_steps", "outer", "inner",
            "bound", "measure", "within",
        ],
    );
    let mut rng = Xoshiro256::new(0x7AB1E1);
    for &w in &[1_000usize, 10_000, 100_000] {
        for &frac in &[0.0f64, 0.2, 0.5, 0.65, 0.9] {
            for name in ["memento", "jump", "anchor", "dx"] {
                let mut algo = scenario::build(name, w, &cfg);
                scenario::apply_removals(
                    algo.as_mut(),
                    (w as f64 * frac) as usize,
                    RemovalOrder::Random,
                    &mut rng,
                );
                let (js, outer, inner) = mean_iters(algo.as_ref(), trials, w as u64);
                let ww = algo.working() as f64;
                let n = algo.size() as f64;
                let (bound, measured) = match name {
                    // E[τ] ≤ 1+ln(n/w) per loop; the product bounds ω.
                    "memento" => ((1.0 + (n / ww).ln()).powi(2), outer.max(1.0) * inner.max(1.0)),
                    "jump" => (ww.ln().max(1.0) + 1.0, js),
                    "anchor" => ((1.0 + (n / ww).ln()).powi(2), outer.max(1.0) * inner.max(1.0)),
                    "dx" => (n / ww, outer),
                    _ => unreachable!(),
                };
                t.push_row(vec![
                    name.into(),
                    w.to_string(),
                    format!("{:.0}", frac * 100.0),
                    format!("{js:.2}"),
                    format!("{outer:.2}"),
                    format!("{inner:.2}"),
                    format!("{bound:.2}"),
                    format!("{measured:.2}"),
                    // Generous x2 slack: bounds are expectations w/ variance.
                    (measured <= bound * 2.0 + 2.0).to_string(),
                ]);
            }
        }
    }
    t.emit("table1_lookup_laws");

    // --- memory laws ------------------------------------------------------
    let mut m = Table::new(
        "Table I — memory laws (state bytes)",
        &["algo", "w", "removed", "state_bytes", "bytes_per_removed", "law"],
    );
    for &w in &[10_000usize, 100_000] {
        for &frac in &[0.0f64, 0.5] {
            for name in ["memento", "jump", "anchor", "dx"] {
                let mut algo = scenario::build(name, w, &cfg);
                let removed = (w as f64 * frac) as usize;
                scenario::apply_removals(
                    algo.as_mut(),
                    removed,
                    RemovalOrder::Random,
                    &mut rng,
                );
                let bytes = algo.state_bytes();
                let per = if removed > 0 { bytes as f64 / removed as f64 } else { 0.0 };
                let law = match name {
                    "memento" => "Θ(r)",
                    "jump" => "Θ(1)",
                    _ => "Θ(a)",
                };
                m.push_row(vec![
                    name.into(),
                    w.to_string(),
                    removed.to_string(),
                    bytes.to_string(),
                    format!("{per:.1}"),
                    law.into(),
                ]);
            }
        }
    }
    m.emit("table1_memory_laws");

    // --- resize-time laws (Θ(1) add/remove for all four) ------------------
    let mut rt = Table::new(
        "Table I — resize time (ns/op, Θ(1) claim)",
        &["algo", "w", "remove_ns", "add_ns"],
    );
    for &w in &[1_000usize, 100_000] {
        for name in ["memento", "jump", "anchor", "dx"] {
            // Measure remove+add pairs: add() is a LIFO restore, so the
            // working set is stationary across pairs and victims can be
            // pre-sampled outside the timed region (O(1) per iteration).
            let mut algo = scenario::build(name, w, &cfg);
            let iters = 20_000usize;
            let mut rng2 = Xoshiro256::new(1);
            let random_ok = algo.supports_random_removal();
            let wb = algo.working_buckets();
            let victims: Vec<u32> = (0..iters)
                .map(|_| {
                    if random_ok {
                        wb[rng2.next_index(wb.len())]
                    } else {
                        *wb.last().unwrap()
                    }
                })
                .collect();
            let t0 = std::time::Instant::now();
            for &b in &victims {
                algo.remove(b).unwrap();
                algo.add().unwrap();
            }
            let per_pair = t0.elapsed().as_nanos() as f64 / iters as f64;
            rt.push_row(vec![
                name.into(),
                w.to_string(),
                format!("{:.0}", per_pair / 2.0),
                format!("{:.0}", per_pair / 2.0),
            ]);
        }
    }
    rt.emit("table1_resize_laws");
}
