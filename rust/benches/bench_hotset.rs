//! Hot-key tier smoke bench — the measurement behind CI's perf-smoke
//! `--hotset` gate and `BENCH_hotset.json`.
//!
//! Cells (all in-process `Service::handle`, the transport-free view of
//! the GET path — the cache sits between route and storage, so the
//! loopback stack would only dilute the effect being measured):
//!
//! * **cached vs uncached GET** under Zipf s ∈ {0.99, 1.2} and a
//!   16-key/90% hot-set shape, multi-threaded. The cached service is
//!   the default construction; the uncached baseline is
//!   `Service::with_options(..., None)`. The headline figure is the
//!   s=1.2 cached cell (`hotset_get_ops_s`) plus its hit rate;
//!   speedups are reported per shape.
//! * **churn staleness** — writer threads do PUT-then-GET on keys they
//!   own and reader threads re-read constant preloaded keys, while an
//!   admin thread cycles KILL/ADD epoch bumps (replication=2). Any
//!   read that returns something other than the owner's last acked
//!   write is a stale read; the gate ceiling for
//!   `hotset_stale_reads` is **0**.
//!
//! Emits `BENCH_hotset.json` at the workspace root (override with
//! `MEMENTO_BENCH_JSON`; cell seconds with `MEMENTO_HOTSET_SECS`, key
//! count with `MEMENTO_HOTSET_KEYS`, threads with
//! `MEMENTO_HOTSET_THREADS`). CI compares the JSON against
//! `ci/perf-baseline.json` floors via `scripts/perf_compare.py
//! --hotset`.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::loadgen::ZipfTable;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Key-rank distribution for a GET cell.
#[derive(Clone)]
enum Shape {
    Zipf(Arc<ZipfTable>),
    /// `p` of the draws hit one of the first `hot` ranks, the rest are
    /// uniform over all `n` — the classic flash-crowd shape.
    Hot { hot: u64, p: f64, n: u64 },
}

impl Shape {
    fn draw(&self, rng: &mut Xoshiro256) -> u64 {
        match self {
            Shape::Zipf(t) => t.sample(rng),
            Shape::Hot { hot, p, n } => {
                if rng.next_f64() < *p {
                    rng.next_u64() % hot
                } else {
                    rng.next_u64() % n
                }
            }
        }
    }
}

fn fresh_service(keys: usize, cached: bool) -> Arc<Service> {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let svc = if cached {
        Service::with_replicas(router, 1)
    } else {
        Service::with_options(router, 1, Default::default(), None)
    };
    for i in 0..keys {
        let r = svc.handle(&format!("PUT hk{i} val{i}"));
        assert!(r.starts_with("OK"), "preload: {r}");
    }
    svc
}

/// Multi-threaded GET throughput for one (service, shape) cell; also
/// returns the cache hit rate over the cell (1.0-denominator-safe, 0
/// on an uncached service).
fn get_cell(svc: &Arc<Service>, shape: &Shape, threads: usize, secs: f64) -> (f64, f64) {
    let (h0, m0) = match &svc.cache {
        Some(c) => {
            let (h, m, _) = c.op_counts();
            (h, m)
        }
        None => (0, 0),
    };
    let stop = Arc::new(AtomicBool::new(false));
    let start = Instant::now();
    let workers: Vec<_> = (0..threads)
        .map(|t| {
            let svc = svc.clone();
            let shape = shape.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(0xB0B5_1DE5 ^ ((t as u64) << 17));
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    for _ in 0..128 {
                        let rank = shape.draw(&mut rng);
                        let r = svc.handle(&format!("GET hk{rank}"));
                        debug_assert!(r.starts_with("VALUE"), "{r}");
                        std::hint::black_box(&r);
                    }
                    ops += 128;
                }
                ops
            })
        })
        .collect();
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    let ops: u64 = workers.into_iter().map(|w| w.join().expect("worker")).sum();
    let tput = ops as f64 / start.elapsed().as_secs_f64();
    let hit_rate = match &svc.cache {
        Some(c) => {
            let (h, m, _) = c.op_counts();
            let (dh, dm) = (h - h0, m - m0);
            dh as f64 / ((dh + dm).max(1)) as f64
        }
        None => 0.0,
    };
    (tput, hit_rate)
}

/// Freshness under churn: every read is checked against the last value
/// its owner acked (writers) or the preloaded constant (readers) while
/// KILL/ADD bumps the epoch. Returns (ops/s, stale reads, epoch bumps).
fn churn_cell(secs: f64) -> (f64, u64, u64) {
    let router = Router::new("memento", 12, 120, None).expect("router");
    let svc = Service::with_replicas(router, 2);
    const OWNED: usize = 256;
    const STABLE: usize = 512;
    for j in 0..STABLE {
        let r = svc.handle(&format!("PUT stable{j} sv{j}"));
        assert!(r.starts_with("OK"), "{r}");
    }
    let stop = Arc::new(AtomicBool::new(false));
    let stale = Arc::new(AtomicU64::new(0));
    let start = Instant::now();

    let writers: Vec<_> = (0..4usize)
        .map(|t| {
            let svc = svc.clone();
            let stop = stop.clone();
            let stale = stale.clone();
            std::thread::spawn(move || {
                let mut ops = 0u64;
                let mut ver = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    ver += 1;
                    for i in 0..OWNED {
                        let r = svc.handle(&format!("PUT w{t}k{i} v{ver}"));
                        assert!(r.starts_with("OK"), "{r}");
                        let r = svc.handle(&format!("GET w{t}k{i}"));
                        // This thread is the key's only writer: anything
                        // but the version it just acked is a stale read.
                        if !r.ends_with(&format!(" v{ver}")) {
                            stale.fetch_add(1, Ordering::Relaxed);
                        }
                        ops += 2;
                    }
                }
                ops
            })
        })
        .collect();
    let readers: Vec<_> = (0..2usize)
        .map(|t| {
            let svc = svc.clone();
            let stop = stop.clone();
            let stale = stale.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(0xFEED ^ t as u64);
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let j = rng.next_u64() as usize % STABLE;
                    let r = svc.handle(&format!("GET stable{j}"));
                    if !r.ends_with(&format!(" sv{j}")) {
                        stale.fetch_add(1, Ordering::Relaxed);
                    }
                    ops += 1;
                }
                ops
            })
        })
        .collect();
    let admin = {
        let svc = svc.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut bumps = 0u64;
            let mut bucket = 1u32;
            while !stop.load(Ordering::Relaxed) {
                let r = svc.handle(&format!("KILL {bucket}"));
                assert!(r.starts_with("KILLED"), "{r}");
                std::thread::sleep(Duration::from_millis(20));
                let r = svc.handle("ADD");
                assert!(r.starts_with("ADDED"), "{r}");
                bumps += 2;
                bucket = 1 + (bucket + 1) % 10;
                std::thread::sleep(Duration::from_millis(20));
            }
            bumps
        })
    };
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    let mut ops: u64 = writers.into_iter().map(|w| w.join().expect("writer")).sum();
    ops += readers.into_iter().map(|r| r.join().expect("reader")).sum::<u64>();
    let bumps = admin.join().expect("admin");
    (ops as f64 / start.elapsed().as_secs_f64(), stale.load(Ordering::Relaxed), bumps)
}

fn main() {
    let secs = env_f64("MEMENTO_HOTSET_SECS", 1.0);
    let keys = env_usize("MEMENTO_HOTSET_KEYS", 50_000);
    let threads = env_usize("MEMENTO_HOTSET_THREADS", 8);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("hot-set smoke: {cores} cores, {threads} threads, {keys} keys, {secs}s per cell\n");

    let cached = fresh_service(keys, true);
    let uncached = fresh_service(keys, false);
    assert!(cached.cache.is_some() && uncached.cache.is_none());

    let shapes: Vec<(&str, Shape)> = vec![
        ("zipf s=0.99", Shape::Zipf(Arc::new(ZipfTable::new(keys as u64, 0.99)))),
        ("zipf s=1.20", Shape::Zipf(Arc::new(ZipfTable::new(keys as u64, 1.2)))),
        ("hot16 p=0.9", Shape::Hot { hot: 16, p: 0.9, n: keys as u64 }),
    ];
    let mut rows = Vec::new();
    for (name, shape) in &shapes {
        let (base, _) = get_cell(&uncached, shape, threads, secs);
        let (fast, hit_rate) = get_cell(&cached, shape, threads, secs);
        let speedup = fast / base.max(1.0);
        println!(
            "{name}: cached {fast:>10.0} ops/s (hit rate {hit_rate:.3}), \
             uncached {base:>10.0} ops/s — {speedup:.2}x"
        );
        rows.push((*name, base, fast, hit_rate, speedup));
    }
    let (_, base12, fast12, hit12, speed12) = rows[1];
    let (_, base099, fast099, _hit099, speed099) = rows[0];
    let (_, basehot, fasthot, _hithot, speedhot) = rows[2];

    let (churn_ops, stale, bumps) = churn_cell(secs.max(1.0) * 2.0);
    println!(
        "\nchurn cell: {churn_ops:.0} ops/s across {bumps} epoch bumps, {stale} stale reads"
    );
    assert_eq!(stale, 0, "the cache served a stale read under churn");
    assert!(bumps >= 2, "the admin thread must drive epoch bumps");

    let json = format!(
        "{{\n  \"bench\": \"hotset\",\n  \"cores\": {cores},\n  \"cell_secs\": {secs},\n  \
         \"keys\": {keys},\n  \"threads\": {threads},\n  \
         \"hotset_get_ops_s\": {fast12:.1},\n  \
         \"hotset_uncached_ops_s\": {base12:.1},\n  \
         \"hotset_speedup_1_2\": {speed12:.2},\n  \
         \"hotset_hit_rate\": {hit12:.4},\n  \
         \"hotset_cached_ops_s_099\": {fast099:.1},\n  \
         \"hotset_uncached_ops_s_099\": {base099:.1},\n  \
         \"hotset_speedup_099\": {speed099:.2},\n  \
         \"hotset_hot16_cached_ops_s\": {fasthot:.1},\n  \
         \"hotset_hot16_uncached_ops_s\": {basehot:.1},\n  \
         \"hotset_hot16_speedup\": {speedhot:.2},\n  \
         \"hotset_churn_ops_s\": {churn_ops:.1},\n  \
         \"hotset_epoch_bumps\": {bumps},\n  \
         \"hotset_stale_reads\": {stale}\n}}\n"
    );
    // Cargo runs bench binaries with CWD = the package root (rust/); the
    // committed reference and the CI gate live at the workspace root.
    let path = std::env::var("MEMENTO_BENCH_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_hotset.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
