//! Connection-scaling smoke bench — the measurement behind CI's
//! `conn-smoke` job and `BENCH_conn.json`.
//!
//! Three cells against a live loopback netserver:
//!
//! * **single-connection text LOOKUP** — one client, one pipelined
//!   text connection, back-to-back lookups;
//! * **single-connection binary LOOKUP** — the same traffic as typed
//!   length-prefixed frames (no line rendering/parsing on the hot
//!   path; the acceptance expectation is binary ≥ text);
//! * **high-connection open-loop** — `MEMENTO_CONN_COUNT` (default
//!   1024) binary connections fanned out from a bounded worker count,
//!   paced at `MEMENTO_CONN_RATE` ops/s total, CO-corrected p99.9.
//!   This is the event-loop contract: connection count is a poller
//!   registration count, not a thread count.
//!
//! Emits `BENCH_conn.json` at the workspace root (override with
//! `MEMENTO_BENCH_JSON`; cell seconds with `MEMENTO_CONN_SECS`). CI
//! compares the JSON against `ci/perf-baseline.json` floors via
//! `scripts/perf_compare.py --conn`.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::loadgen::{self, ChurnScenario, LoadgenConfig, Mode, Workload};
use memento::netserver::{Client, ServerConfig};
use memento::proto::Request;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn fresh_server(max_conns: usize) -> (Arc<Service>, memento::netserver::ServerHandle) {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let service = Service::with_replicas(router, 1);
    let server = service
        .serve_config("127.0.0.1:0", ServerConfig { max_conns, ..Default::default() })
        .expect("bind");
    (service, server)
}

/// Back-to-back LOOKUPs on one connection for `secs`: ops/s.
fn single_conn_cell(binary: bool, secs: f64) -> f64 {
    let (_svc, server) = fresh_server(8);
    let mut client = if binary {
        Client::connect_binary(&server.addr()).expect("connect")
    } else {
        Client::connect(&server.addr()).expect("connect")
    };
    let deadline = Instant::now() + Duration::from_secs_f64(secs);
    let start = Instant::now();
    let mut ops = 0u64;
    let mut key = 1u64;
    while Instant::now() < deadline {
        for _ in 0..256 {
            if binary {
                client.call(&Request::Lookup { key }).expect("binary lookup");
            } else {
                let resp = client.call(&Request::Lookup { key }).expect("text lookup");
                assert!(
                    matches!(resp, memento::proto::Response::Bucket { .. }),
                    "unexpected response {resp:?}"
                );
            }
            key = key.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        ops += 256;
    }
    let tput = ops as f64 / start.elapsed().as_secs_f64();
    drop(client);
    server.shutdown();
    tput
}

/// Open-loop traffic over `conns` binary connections from 8 workers:
/// (achieved ops/s, CO-corrected p99.9 in microseconds, live conns,
/// server worker threads).
fn high_conn_cell(conns: usize, rate: f64, secs: f64) -> (f64, f64, usize, usize) {
    memento::netserver::raise_fd_limit();
    let threads = 8usize;
    let (_svc, server) = fresh_server(conns + 16);
    let per_worker = conns.div_ceil(threads);
    let factory = loadgen::target::fanout_factory(server.addr(), per_worker, true);
    loadgen::preload(&factory, 10_000).expect("preload");
    let cfg = LoadgenConfig {
        mode: Mode::Open { rate },
        workload: Workload::uniform(100_000, 0.7),
        threads,
        duration: Duration::from_secs_f64(secs),
        churn: ChurnScenario::Stable,
        ..LoadgenConfig::default()
    };
    let rep = loadgen::run(&cfg, &factory).expect("open-loop run");
    assert_eq!(rep.errors, 0, "conn smoke run must be error-free");
    let live = server.live_connections();
    let workers = server.worker_threads();
    let tput = rep.throughput();
    let p999_us = rep.corrected.quantile(0.999) as f64 / 1_000.0;
    server.shutdown();
    (tput, p999_us, live, workers)
}

fn main() {
    let secs = env_f64("MEMENTO_CONN_SECS", 1.0);
    let rate = env_f64("MEMENTO_CONN_RATE", 20_000.0);
    let conns = env_usize("MEMENTO_CONN_COUNT", 1024);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("connection smoke: {cores} cores, {secs}s per cell, {conns} conns @ {rate} ops/s\n");

    let text = single_conn_cell(false, secs);
    println!("single-conn text LOOKUP:   {text:>10.0} ops/s");
    let bin = single_conn_cell(true, secs);
    println!("single-conn binary LOOKUP: {bin:>10.0} ops/s ({:.2}x text)", bin / text.max(1.0));

    let (open_tput, p999_us, live, workers) = high_conn_cell(conns, rate, secs.max(1.0) * 2.0);
    println!(
        "{conns}-conn open loop:      {open_tput:>10.0} ops/s, p99.9 {p999_us:.0}us \
         ({live} live conns on {workers} worker threads)"
    );
    assert!(
        live >= conns,
        "expected all {conns} connections open at end of run, saw {live}"
    );

    let json = format!(
        "{{\n  \"bench\": \"conn\",\n  \"cores\": {cores},\n  \"cell_secs\": {secs},\n  \
         \"conns\": {conns},\n  \"rate\": {rate},\n  \
         \"worker_threads\": {workers},\n  \
         \"conn_text_lookup_ops_s\": {text:.1},\n  \
         \"conn_bin_lookup_ops_s\": {bin:.1},\n  \
         \"bin_vs_text\": {:.2},\n  \
         \"conn_1k_ops_s\": {open_tput:.1},\n  \
         \"conn_p999_us\": {p999_us:.1}\n}}\n",
        bin / text.max(1.0)
    );
    // Cargo runs bench binaries with CWD = the package root (rust/); the
    // committed reference and the CI gate live at the workspace root.
    let path = std::env::var("MEMENTO_BENCH_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_conn.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
