//! Migration-pipeline smoke bench — the measurement behind the CI
//! perf-smoke gate's `BENCH_migration.json` (DESIGN.md §9).
//!
//! For each removed fraction of a preloaded cluster, the manual-mode
//! migrator splits the two halves of a membership change apart and times
//! them separately:
//!
//! * **admin (plan)** — the `KILL`/`ADD` protocol call: publish the new
//!   epoch, derive the moved-key delta, enqueue the plan. Must be O(1)
//!   in stored keys — the gate's `migration_admin_ops_s` floor trips if
//!   key scanning ever creeps back onto this path.
//! * **drain (execute)** — `Migrator::run_pending()`: batched planning
//!   (`route_batch`) plus per-shard extraction and relocation. Gated as
//!   throughput via `migration_drain_keys_per_s`.
//!
//! Emits `results/migration.csv` plus `BENCH_migration.json` (override
//! the JSON path with `MEMENTO_MIGRATION_JSON`; preload size with
//! `MEMENTO_MIGRATION_PRELOAD`). CI compares the JSON against
//! `ci/perf-baseline.json` and fails on a >2x regression.

use memento::benchkit::report::Table;
use memento::coordinator::migration::MigrationConfig;
use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use std::time::Instant;

const NODES: usize = 32;
/// Removed fractions: 1, 4 and 8 of 32 nodes.
const FRACS: [f64; 3] = [0.03125, 0.125, 0.25];

struct Cell {
    frac: f64,
    kills: usize,
    admin_ns_avg: f64,
    admin_ns_max: u64,
    drain_keys: u64,
    drain_ms: f64,
    drain_keys_per_s: f64,
}

fn run_cell(frac: f64, preload: u64) -> Cell {
    let kills = ((NODES as f64 * frac).round() as usize).max(1);
    let router = Router::new("memento", NODES, NODES * 10, None).expect("router");
    let svc = Service::with_migration(
        router,
        1,
        MigrationConfig { auto: false, ..MigrationConfig::default() },
    );
    for i in 0..preload {
        svc.handle(&format!("PUT k{i} v{i}"));
    }

    // Admin half: kills, drain, restores, drain — every admin rtt
    // sampled, every executed plan's keys counted.
    let mut admin_ns: Vec<u64> = Vec::with_capacity(2 * kills);
    let mut admin = |line: &str| {
        let t0 = Instant::now();
        let resp = svc.handle(line);
        admin_ns.push(memento::metrics::duration_to_ns(t0.elapsed()));
        assert!(
            resp.starts_with("KILLED") || resp.starts_with("ADDED"),
            "admin command failed: {resp}"
        );
    };
    for b in 0..kills {
        admin(&format!("KILL {b}"));
    }
    let t0 = Instant::now();
    let moved_out = svc.migration.run_pending();
    let mut drain = t0.elapsed();
    for _ in 0..kills {
        admin("ADD");
    }
    let t0 = Instant::now();
    let moved_back = svc.migration.run_pending();
    drain += t0.elapsed();

    let drain_keys = moved_out + moved_back;
    let drain_ms = drain.as_secs_f64() * 1e3;
    let admin_ns_avg = admin_ns.iter().sum::<u64>() as f64 / admin_ns.len() as f64;
    let admin_ns_max = admin_ns.iter().copied().max().unwrap_or(0);
    assert!(drain_keys > 0, "churn over a preloaded cluster must move keys");
    Cell {
        frac,
        kills,
        admin_ns_avg,
        admin_ns_max,
        drain_keys,
        drain_ms,
        drain_keys_per_s: drain_keys as f64 / drain.as_secs_f64().max(1e-9),
    }
}

fn main() {
    let preload: u64 = std::env::var("MEMENTO_MIGRATION_PRELOAD")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60_000);
    println!("migration smoke: {NODES} nodes, {preload} preloaded records\n");

    let mut table = Table::new(
        "migration",
        &[
            "removed_frac",
            "kills",
            "admin_ns_avg",
            "admin_ns_max",
            "drain_keys",
            "drain_ms",
            "drain_keys_per_s",
        ],
    );
    let mut cells = Vec::new();
    for &frac in &FRACS {
        let c = run_cell(frac, preload);
        table.push_row(vec![
            format!("{:.5}", c.frac),
            c.kills.to_string(),
            format!("{:.0}", c.admin_ns_avg),
            c.admin_ns_max.to_string(),
            c.drain_keys.to_string(),
            format!("{:.3}", c.drain_ms),
            format!("{:.0}", c.drain_keys_per_s),
        ]);
        cells.push(c);
    }
    table.emit("migration");

    // Gate figures: the slowest cell bounds both metrics.
    let mut admin_ops_s_min = f64::INFINITY;
    let mut drain_keys_per_s_min = f64::INFINITY;
    for c in &cells {
        admin_ops_s_min = admin_ops_s_min.min(1e9 / c.admin_ns_avg.max(1.0));
        drain_keys_per_s_min = drain_keys_per_s_min.min(c.drain_keys_per_s);
    }
    println!(
        "admin ops/s (worst cell): {admin_ops_s_min:.0}, \
         drain keys/s (worst cell): {drain_keys_per_s_min:.0}"
    );

    let cell_rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{{\"removed_frac\": {:.5}, \"kills\": {}, \"admin_ns_avg\": {:.0}, \
                 \"admin_ns_max\": {}, \"drain_keys\": {}, \"drain_ms\": {:.3}, \
                 \"drain_keys_per_s\": {:.1}}}",
                c.frac, c.kills, c.admin_ns_avg, c.admin_ns_max, c.drain_keys, c.drain_ms,
                c.drain_keys_per_s
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"migration\",\n  \"algo\": \"memento\",\n  \"nodes\": {NODES},\n  \
         \"preload\": {preload},\n  \"cells\": [\n    {}\n  ],\n  \
         \"admin_ops_s_min\": {admin_ops_s_min:.1},\n  \
         \"drain_keys_per_s_min\": {drain_keys_per_s_min:.1}\n}}\n",
        cell_rows.join(",\n    ")
    );
    // Like bench_router_scaling: the committed reference and the CI gate
    // live at the workspace root, and a failed write must fail the bench
    // so a stale reference can never pass the gate silently.
    let path = std::env::var("MEMENTO_MIGRATION_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_migration.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
