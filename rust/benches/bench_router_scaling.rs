//! Router thread-scaling smoke bench — the measurement behind CI's
//! `perf-smoke` job and `BENCH_router_scaling.json`.
//!
//! Three sweeps over 1/2/4/8 worker threads:
//!
//! * **closed-loop loadgen** against an in-process replicated service
//!   (no TCP: isolates router + sharded storage scaling — the data path
//!   this repo made wait-free, DESIGN.md §8);
//! * **closed-loop loadgen over TCP** against the event-driven
//!   netserver on loopback — the same traffic with real framing, the
//!   epoll loop, and the worker pool in the path (the informational
//!   `tcp_vs_inproc_8t` ratio is the whole-stack protocol overhead);
//! * **route-only**: threads hammering `Router::route` back to back —
//!   the bare wait-free snapshot path with no storage behind it.
//!
//! Emits `results/router_scaling.csv` plus `BENCH_router_scaling.json`
//! (override the JSON path with `MEMENTO_BENCH_JSON`; cell seconds with
//! `MEMENTO_SMOKE_SECS`). CI compares the JSON against the committed
//! `ci/perf-baseline.json` and fails on a >2x throughput regression.
//! Scaling ratios saturate at the machine's core count — interpret the
//! 8-thread column on a 2-core runner accordingly.

use memento::benchkit::report::Table;
use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::loadgen::{self, LoadgenConfig, Mode, Workload};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// One closed-loop loadgen cell: (ops, throughput ops/s, p99 ns).
fn loadgen_cell(threads: usize, secs: f64) -> (u64, f64, u64) {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let service = Service::with_replicas(router, 2);
    let factory = loadgen::target::inproc_factory(service);
    loadgen::preload(&factory, 10_000).expect("preload");
    let cfg = LoadgenConfig {
        mode: Mode::Closed,
        workload: Workload::uniform(100_000, 0.7),
        threads,
        duration: Duration::from_secs_f64(secs),
        ..LoadgenConfig::default()
    };
    let rep = loadgen::run(&cfg, &factory).expect("loadgen run");
    assert_eq!(rep.errors, 0, "smoke run must be error-free");
    (rep.ops, rep.throughput(), rep.corrected.quantile(0.99))
}

/// One closed-loop loadgen cell over loopback TCP: throughput ops/s.
fn tcp_cell(threads: usize, secs: f64) -> f64 {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let service = Service::with_replicas(router, 2);
    let server = service.serve("127.0.0.1:0", threads + 8).expect("bind");
    let factory = loadgen::target::tcp_factory(server.addr());
    loadgen::preload(&factory, 10_000).expect("preload");
    let cfg = LoadgenConfig {
        mode: Mode::Closed,
        workload: Workload::uniform(100_000, 0.7),
        threads,
        duration: Duration::from_secs_f64(secs),
        ..LoadgenConfig::default()
    };
    let rep = loadgen::run(&cfg, &factory).expect("tcp loadgen run");
    assert_eq!(rep.errors, 0, "tcp smoke run must be error-free");
    let tput = rep.throughput();
    server.shutdown();
    tput
}

/// One route-only cell: throughput of bare `Router::route` calls.
fn route_only_cell(threads: usize, secs: f64) -> f64 {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let stop = Arc::new(AtomicBool::new(false));
    let start = Instant::now();
    let workers: Vec<_> = (0..threads)
        .map(|w| {
            let router = router.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut ops = 0u64;
                let mut k = (w as u64 + 1) << 40;
                while !stop.load(Ordering::Relaxed) {
                    for _ in 0..256 {
                        let key = memento::hashing::mix::splitmix64_mix(k);
                        std::hint::black_box(router.route(key));
                        k += 1;
                    }
                    ops += 256;
                }
                ops
            })
        })
        .collect();
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    let total: u64 = workers.into_iter().map(|w| w.join().expect("route worker")).sum();
    total as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    let secs: f64 = std::env::var("MEMENTO_SMOKE_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("router scaling smoke: {cores} cores, {secs}s per loadgen cell\n");

    let mut table = Table::new(
        "router_scaling",
        &[
            "threads",
            "loadgen_ops",
            "loadgen_ops_s",
            "loadgen_p99_ns",
            "tcp_ops_s",
            "route_only_ops_s",
        ],
    );
    let mut loadgen_rows = Vec::new();
    let mut tcp_rows = Vec::new();
    let mut route_rows = Vec::new();
    let mut loadgen_tputs = Vec::new();
    let mut tcp_tputs = Vec::new();
    let mut route_tputs = Vec::new();
    for &t in &THREADS {
        let (ops, tput, p99) = loadgen_cell(t, secs);
        let tcp = tcp_cell(t, secs * 0.6);
        let route = route_only_cell(t, secs * 0.4);
        table.push_row(vec![
            t.to_string(),
            ops.to_string(),
            format!("{tput:.0}"),
            p99.to_string(),
            format!("{tcp:.0}"),
            format!("{route:.0}"),
        ]);
        loadgen_rows.push(format!(
            "{{\"threads\": {t}, \"ops\": {ops}, \"throughput\": {tput:.1}, \"p99_ns\": {p99}}}"
        ));
        tcp_rows.push(format!("{{\"threads\": {t}, \"throughput\": {tcp:.1}}}"));
        route_rows.push(format!("{{\"threads\": {t}, \"throughput\": {route:.1}}}"));
        loadgen_tputs.push(tput);
        tcp_tputs.push(tcp);
        route_tputs.push(route);
    }
    table.emit("router_scaling");

    let loadgen_speedup = loadgen_tputs[THREADS.len() - 1] / loadgen_tputs[0].max(1.0);
    let route_speedup = route_tputs[THREADS.len() - 1] / route_tputs[0].max(1.0);
    // Informational: how much of the in-process throughput survives the
    // whole TCP stack (framing + event loop + worker pool) at 8 threads.
    let tcp_vs_inproc =
        tcp_tputs[THREADS.len() - 1] / loadgen_tputs[THREADS.len() - 1].max(1.0);
    println!("\nspeedup 8 threads vs 1: loadgen {loadgen_speedup:.2}x, route-only {route_speedup:.2}x");
    println!("tcp vs inproc at 8 threads: {tcp_vs_inproc:.2}x");

    let json = format!(
        "{{\n  \"bench\": \"router_scaling\",\n  \"algo\": \"memento\",\n  \"nodes\": 16,\n  \
         \"cores\": {cores},\n  \"cell_secs\": {secs},\n  \
         \"loadgen_closed\": [\n    {}\n  ],\n  \"loadgen_tcp\": [\n    {}\n  ],\n  \
         \"route_only\": [\n    {}\n  ],\n  \
         \"loadgen_speedup_8v1\": {loadgen_speedup:.2},\n  \
         \"route_speedup_8v1\": {route_speedup:.2},\n  \
         \"tcp_vs_inproc_8t\": {tcp_vs_inproc:.2}\n}}\n",
        loadgen_rows.join(",\n    "),
        tcp_rows.join(",\n    "),
        route_rows.join(",\n    ")
    );
    // Cargo runs bench binaries with CWD = the package root (rust/), but
    // the committed reference and the CI gate live at the workspace root:
    // resolve the default there so the fresh measurement overwrites the
    // file perf_compare.py actually reads.
    let path = std::env::var("MEMENTO_BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/../BENCH_router_scaling.json", env!("CARGO_MANIFEST_DIR"))
    });
    // A failed write must fail the bench: the default path is a committed
    // reference file, and a green step that silently left stale figures
    // in place would let the CI perf gate pass against the wrong data.
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
