//! Observability overhead bench — proves the per-stage span
//! instrumentation does not tax the wait-free read path (DESIGN.md §12).
//!
//! Two cells over the same key stream against one router:
//!
//! * `raw`  — `router.route(key)` alone, the PR-6 hot path;
//! * `span` — the instrumented call-site shape the service uses:
//!   `obs::timer(Stage::Route)` (1-in-`SAMPLE_PERIOD` sampled), the
//!   route, then the timer drop.
//!
//! The cells run interleaved (raw, span, raw, span, …) for several
//! rounds and each takes its best round, so CPU-frequency drift on a
//! shared runner biases neither side. CI gates the span cell's absolute
//! throughput (floor) and the relative overhead (ceiling,
//! `obs_route_overhead_pct_max` in `ci/perf-baseline.json`).
//!
//! Emits `results/obs.csv` plus `BENCH_obs.json` (path override
//! `MEMENTO_OBS_JSON`; key count `MEMENTO_OBS_KEYS`).

use memento::benchkit::{black_box, report::Table};
use memento::coordinator::router::Router;
use memento::hashing::mix::splitmix64_mix;
use memento::obs::{self, Stage};
use std::time::Instant;

const NODES: usize = 64;
const ROUNDS: usize = 5;

fn run_raw(router: &Router, keys: u64) -> f64 {
    let t0 = Instant::now();
    let mut acc = 0u64;
    for i in 0..keys {
        let (b, _node) = router.route(splitmix64_mix(i));
        acc ^= u64::from(b);
    }
    black_box(acc);
    keys as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn run_span(router: &Router, keys: u64) -> f64 {
    let t0 = Instant::now();
    let mut acc = 0u64;
    for i in 0..keys {
        let t = obs::timer(Stage::Route);
        let (b, _node) = router.route(splitmix64_mix(i));
        drop(t);
        acc ^= u64::from(b);
    }
    black_box(acc);
    keys as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let keys: u64 = std::env::var("MEMENTO_OBS_KEYS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let router = Router::new("memento", NODES, NODES * 10, None).expect("router");
    println!(
        "obs smoke: {keys} routes on {NODES} nodes, raw vs spanned \
         (1-in-{} sampling), best of {ROUNDS} interleaved rounds\n",
        obs::SAMPLE_PERIOD
    );

    // Warm-up: fault in the table and let the branch predictors settle
    // before anything is timed.
    run_raw(&router, keys / 10);

    let (mut raw_best, mut span_best) = (0.0f64, 0.0f64);
    for _ in 0..ROUNDS {
        raw_best = raw_best.max(run_raw(&router, keys));
        span_best = span_best.max(run_span(&router, keys));
    }
    let overhead_pct = (raw_best / span_best.max(1e-9) - 1.0) * 100.0;

    let mut table = Table::new("obs", &["cell", "keys", "ops_per_s", "ns_per_op"]);
    for (cell, ops) in [("raw", raw_best), ("span", span_best)] {
        table.push_row(vec![
            cell.to_string(),
            keys.to_string(),
            format!("{ops:.0}"),
            format!("{:.2}", 1e9 / ops.max(1e-9)),
        ]);
    }
    table.emit("obs");
    println!(
        "span overhead: {overhead_pct:.2}% ({:.0} -> {:.0} ops/s)",
        raw_best, span_best
    );

    let json = format!(
        "{{\n  \"bench\": \"obs\",\n  \"keys\": {keys},\n  \"nodes\": {NODES},\n  \
         \"sample_period\": {},\n  \"obs_route_raw_ops_s\": {raw_best:.1},\n  \
         \"obs_route_span_ops_s\": {span_best:.1},\n  \
         \"obs_route_overhead_pct\": {overhead_pct:.3}\n}}\n",
        obs::SAMPLE_PERIOD
    );
    // Like the other perf-smoke benches: the gate input lives at the
    // workspace root, and a failed write must fail the bench.
    let path = std::env::var("MEMENTO_OBS_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_obs.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
