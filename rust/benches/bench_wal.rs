//! WAL group-commit smoke bench — the measurement behind the CI
//! perf-smoke gate's `BENCH_wal.json` (DESIGN.md §11.2).
//!
//! One durable 16-shard `StorageNode`, one single-threaded writer, one
//! cell per fsync policy: `always` (fsync before every ack — the
//! durability ceiling the crash drills rely on), `batch(8|64|512)`
//! (group commit: one fsync amortized over N appends) and `osonly`
//! (no explicit fsync — the page-cache throughput bound). The spread
//! between `always` and the batch cells is the group-commit win; the
//! gap to `osonly` is what fsync latency still costs.
//!
//! Emits `results/wal.csv` plus `BENCH_wal.json` (override the JSON
//! path with `MEMENTO_WAL_JSON`; record count with
//! `MEMENTO_WAL_RECORDS`). CI gates the `batch64` and `osonly` cells
//! against `ci/perf-baseline.json` — `always` is reported but not
//! gated: its figure is the runner's raw fsync latency, which varies
//! by an order of magnitude across shared-runner disks.

use memento::benchkit::report::Table;
use memento::coordinator::storage::StorageNode;
use memento::coordinator::wal::{FsyncPolicy, WalOptions};
use memento::hashing::mix::splitmix64_mix;
use memento::metrics::WalMetrics;
use std::sync::Arc;
use std::time::Instant;

const VALUE_BYTES: usize = 64;

struct Cell {
    policy: &'static str,
    records: u64,
    ms: f64,
    puts_per_s: f64,
    fsyncs: u64,
    group_commits: u64,
}

fn run_cell(policy: FsyncPolicy, label: &'static str, records: u64) -> Cell {
    let dir = std::env::temp_dir()
        .join(format!("memento-bench-wal-{}-{label}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let metrics = Arc::new(WalMetrics::new());
    let (node, _stats) = StorageNode::durable(
        &dir,
        WalOptions { fsync: policy, compact_bytes: 0 },
        metrics.clone(),
    )
    .expect("open durable node");
    let value = vec![0x5A_u8; VALUE_BYTES];
    let t0 = Instant::now();
    for i in 0..records {
        node.put(splitmix64_mix(i), value.clone());
    }
    // The batch/osonly tails pay their deferred fsyncs inside the timed
    // window, so every cell ends with the same on-disk guarantee.
    node.sync();
    let elapsed = t0.elapsed();
    assert_eq!(node.len() as u64, records, "every put must land");
    drop(node);
    let _ = std::fs::remove_dir_all(&dir);
    Cell {
        policy: label,
        records,
        ms: elapsed.as_secs_f64() * 1e3,
        puts_per_s: records as f64 / elapsed.as_secs_f64().max(1e-9),
        fsyncs: metrics.fsyncs.get(),
        group_commits: metrics.group_commits.get(),
    }
}

fn main() {
    let records: u64 = std::env::var("MEMENTO_WAL_RECORDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8_000);
    println!(
        "wal smoke: {records} puts of {VALUE_BYTES} B over {} shards, one writer\n",
        StorageNode::SHARDS
    );

    let cells: Vec<Cell> = [
        (FsyncPolicy::Always, "always"),
        (FsyncPolicy::Batch(8), "batch8"),
        (FsyncPolicy::Batch(64), "batch64"),
        (FsyncPolicy::Batch(512), "batch512"),
        (FsyncPolicy::OsOnly, "osonly"),
    ]
    .into_iter()
    .map(|(p, label)| run_cell(p, label, records))
    .collect();

    let mut table = Table::new(
        "wal",
        &["policy", "records", "ms", "puts_per_s", "fsyncs", "group_commits"],
    );
    for c in &cells {
        table.push_row(vec![
            c.policy.to_string(),
            c.records.to_string(),
            format!("{:.3}", c.ms),
            format!("{:.0}", c.puts_per_s),
            c.fsyncs.to_string(),
            c.group_commits.to_string(),
        ]);
    }
    table.emit("wal");

    let by = |label: &str| {
        cells.iter().find(|c| c.policy == label).expect("cell")
    };
    let always = by("always");
    let batch64 = by("batch64");
    let osonly = by("osonly");
    let speedup = batch64.puts_per_s / always.puts_per_s.max(1e-9);
    println!(
        "group-commit speedup batch64 vs always: {speedup:.1}x \
         ({:.0} -> {:.0} puts/s; {} -> {} fsyncs)",
        always.puts_per_s, batch64.puts_per_s, always.fsyncs, batch64.fsyncs
    );

    let cell_rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{{\"policy\": \"{}\", \"records\": {}, \"ms\": {:.3}, \
                 \"puts_per_s\": {:.1}, \"fsyncs\": {}, \"group_commits\": {}}}",
                c.policy, c.records, c.ms, c.puts_per_s, c.fsyncs, c.group_commits
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"wal\",\n  \"shards\": {},\n  \"records\": {records},\n  \
         \"value_bytes\": {VALUE_BYTES},\n  \"cells\": [\n    {}\n  ],\n  \
         \"wal_batch_puts_per_s\": {:.1},\n  \"wal_osonly_puts_per_s\": {:.1},\n  \
         \"wal_group_commit_speedup\": {speedup:.2}\n}}\n",
        StorageNode::SHARDS,
        cell_rows.join(",\n    "),
        batch64.puts_per_s,
        osonly.puts_per_s
    );
    // Like the other perf-smoke benches: the committed reference and the
    // CI gate live at the workspace root, and a failed write must fail
    // the bench so a stale reference can never pass the gate silently.
    let path = std::env::var("MEMENTO_WAL_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_wal.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
