//! Weighted-membership smoke bench — the measurement behind the CI
//! perf-smoke gate's `BENCH_weighted.json` (DESIGN.md §10).
//!
//! For each weight skew (the heaviest node's weight vs the weight-1
//! rest), an 8-node router is resized and measured on two axes:
//!
//! * **lookup throughput** — scalar `route()` over mixed keys. Weighting
//!   lives entirely in the node layer (more buckets, same algorithm), so
//!   the hot path must not regress as skew grows; the gate's
//!   `weighted_lookup_ops_s` floor trips if it does.
//! * **balance error** — max relative deviation of any node's observed
//!   key share from its weight share `w/Σw`. Gated as an absolute
//!   ceiling (`weighted_balance_err_max`): the bucket-set construction
//!   must track the configured weights.
//!
//! Emits `results/weighted.csv` plus `BENCH_weighted.json` (override the
//! JSON path with `MEMENTO_WEIGHTED_JSON`; key count with
//! `MEMENTO_WEIGHTED_KEYS`). CI compares the JSON against
//! `ci/perf-baseline.json`.

use memento::benchkit::report::Table;
use memento::coordinator::router::Router;
use std::time::Instant;

const NODES: usize = 8;
/// Heaviest node's weight; the other 7 nodes stay at weight 1.
const SKEWS: [u32; 4] = [1, 2, 4, 8];

struct Cell {
    skew: u32,
    buckets: usize,
    lookup_ops_s: f64,
    balance_err_max: f64,
}

fn run_cell(skew: u32, keys: u64) -> Cell {
    let router = Router::new("memento", NODES, NODES * 32, None).expect("router");
    let heavy = router.with_view(|_a, m| m.node_at(0)).expect("node 0");
    if skew > 1 {
        router.set_weight(heavy, skew).expect("resize");
    }
    let (buckets, total_weight) = router.with_view(|a, m| (a.working(), m.total_weight()));

    // Balance: per-node key counts over the probe set.
    let mut counts = std::collections::BTreeMap::new();
    let probe: Vec<u64> = (0..keys).map(memento::hashing::mix::splitmix64_mix).collect();
    for &k in &probe {
        let (_b, node) = router.route(k);
        *counts.entry(node).or_insert(0u64) += 1;
    }
    let mut balance_err_max = 0.0f64;
    router.with_view(|_a, m| {
        for info in m.nodes() {
            let held = counts.get(&info.id).copied().unwrap_or(0);
            let share = held as f64 / keys as f64;
            let want = f64::from(info.weight) / total_weight as f64;
            balance_err_max = balance_err_max.max((share - want).abs() / want);
        }
    });

    // Throughput: timed scalar route() sweep over the same keys.
    let t0 = Instant::now();
    let mut sink = 0u64;
    for &k in &probe {
        sink = sink.wrapping_add(u64::from(router.route(k).0));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    assert!(sink > 0, "routing must touch every key");
    Cell {
        skew,
        buckets,
        lookup_ops_s: keys as f64 / elapsed.max(1e-9),
        balance_err_max,
    }
}

fn main() {
    let keys: u64 = std::env::var("MEMENTO_WEIGHTED_KEYS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    println!("weighted smoke: {NODES} nodes, heaviest-node skews {SKEWS:?}, {keys} keys\n");

    let mut table =
        Table::new("weighted", &["skew", "buckets", "lookup_ops_s", "balance_err_max"]);
    let mut cells = Vec::new();
    for &skew in &SKEWS {
        let c = run_cell(skew, keys);
        println!(
            "skew {:>2}: {:>2} buckets, {:>12.0} lookups/s, balance err {:.4}",
            c.skew, c.buckets, c.lookup_ops_s, c.balance_err_max
        );
        table.push_row(vec![
            c.skew.to_string(),
            c.buckets.to_string(),
            format!("{:.0}", c.lookup_ops_s),
            format!("{:.4}", c.balance_err_max),
        ]);
        cells.push(c);
    }
    table.emit("weighted");

    let mut lookup_ops_s_min = f64::INFINITY;
    let mut balance_err_max = 0.0f64;
    for c in &cells {
        lookup_ops_s_min = lookup_ops_s_min.min(c.lookup_ops_s);
        balance_err_max = balance_err_max.max(c.balance_err_max);
    }
    println!(
        "\nlookup ops/s (worst cell): {lookup_ops_s_min:.0}, \
         balance err (worst cell): {balance_err_max:.4}"
    );

    let cell_rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{{\"skew\": {}, \"buckets\": {}, \"lookup_ops_s\": {:.1}, \
                 \"balance_err_max\": {:.5}}}",
                c.skew, c.buckets, c.lookup_ops_s, c.balance_err_max
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"weighted\",\n  \"algo\": \"memento\",\n  \"nodes\": {NODES},\n  \
         \"keys\": {keys},\n  \"cells\": [\n    {}\n  ],\n  \
         \"lookup_ops_s_min\": {lookup_ops_s_min:.1},\n  \
         \"balance_err_max\": {balance_err_max:.5}\n}}\n",
        cell_rows.join(",\n    ")
    );
    // Like bench_migration: the committed reference and the CI gate live
    // at the workspace root, and a failed write must fail the bench so a
    // stale reference can never pass the gate silently.
    let path = std::env::var("MEMENTO_WEIGHTED_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_weighted.json", env!("CARGO_MANIFEST_DIR")));
    match std::fs::write(&path, &json) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => {
            eprintln!("[write {path} failed: {e}]");
            std::process::exit(1);
        }
    }
}
