//! Figs. 19-22 — one-shot removal of 90% of the nodes, best (LIFO) and
//! worst (random) case: memory usage (19/20) and lookup time (21/22).
//!
//! Paper shape: best case, Memento+Jump flat & tiny memory, fast lookups;
//! worst case, Memento's memory grows with r but stays below Anchor/Dx,
//! Anchor slightly ahead of Memento on lookups, Dx slowest.

use memento::simulator::{figures, Scale, ScenarioConfig};

fn main() {
    let scale = Scale::from_env();
    let cfg = ScenarioConfig::default();
    figures::fig_19_22_oneshot(scale, &cfg).emit("fig_19_22_oneshot");
}
