//! Integration: the crash-drill harness itself (DESIGN.md §11.4). Each
//! test spawns the real `memento` binary as an armed child, aborts it
//! at a deterministic crash site, recovers from the surviving files and
//! checks the acked-write invariant. A failure prints the seed — rerun
//! with `memento crashdrill --site <site> --seed <seed>`.

use memento::testkit::crashdrill::{
    run_drill, DrillConfig, MIGRATION_BATCH, MIGRATION_INSTALL, WAL_APPEND, WAL_PRE_FSYNC,
};

const CHILD: &str = env!("CARGO_BIN_EXE_memento");

fn scratch(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("memento-itdrill-{}-{name}", std::process::id()))
}

fn assert_drill_passes(cfg: &DrillConfig) {
    let rep = run_drill(cfg).unwrap_or_else(|e| {
        panic!("drill {}:{:#x} failed to run: {e}", cfg.site, cfg.seed)
    });
    assert!(
        rep.pass(),
        "crash drill failed — reproduce with `memento crashdrill --site {} --seed {}`\n  {}\n  lost: {:?}",
        cfg.site,
        cfg.seed,
        rep.summary(),
        rep.lost
    );
}

/// The acceptance drill: abort the executor between install and extract
/// (the copy-install-remove double-copy window) mid-drain of a killed
/// node. Recovery must replay the logged plan with zero acked-write
/// loss and zero stranded movers.
#[test]
fn kill_between_install_and_extract_recovers_losslessly() {
    let mut cfg =
        DrillConfig::new(0xA11CE, MIGRATION_INSTALL, scratch("install"), CHILD);
    cfg.preload = 900;
    cfg.keyspace = 540;
    let rep = run_drill(&cfg).expect("drill must run");
    assert!(
        rep.pass(),
        "reproduce with `memento crashdrill --site {} --seed {}`\n  {}\n  lost: {:?}",
        cfg.site,
        cfg.seed,
        rep.summary(),
        rep.lost
    );
    assert!(rep.admin_acked, "the KILLN was acked before the crash");
    assert_eq!(rep.plans_replayed, 1, "the half-finished drain must replay");
    assert_eq!(rep.coverage_missed, 0, "delta_coverage missed == 0 post-recovery");
}

/// Abort at a batch boundary: the plan is half-executed with some
/// batches fully moved and the rest untouched.
#[test]
fn kill_at_a_migration_batch_boundary_recovers_losslessly() {
    let mut cfg = DrillConfig::new(0xBA7C4, MIGRATION_BATCH, scratch("batch"), CHILD);
    cfg.preload = 900;
    cfg.keyspace = 540;
    assert_drill_passes(&cfg);
}

/// Abort right after a record's bytes are written (pre-fsync page-cache
/// state) and inside the commit path before the fsync call, across a
/// few seeds each — every acked PUT must survive.
#[test]
fn kills_inside_the_wal_write_path_lose_no_acked_write() {
    for (i, site) in [WAL_APPEND, WAL_PRE_FSYNC].into_iter().enumerate() {
        for seed in [3u64, 0x5EED] {
            let mut cfg =
                DrillConfig::new(seed, site, scratch(&format!("wal{i}-{seed:x}")), CHILD);
            cfg.nodes = 6;
            cfg.preload = 500;
            cfg.keyspace = 300;
            assert_drill_passes(&cfg);
        }
    }
}

/// A site the child never visits must be flagged as a drill
/// configuration bug (the child exits instead of dying by signal).
#[test]
fn a_drill_that_never_crashes_is_an_error() {
    let mut cfg =
        DrillConfig::new(7, "no-such-site", scratch("nocrash"), CHILD);
    cfg.preload = 50;
    cfg.keyspace = 50;
    let err = run_drill(&cfg).expect_err("an unvisited site cannot pass");
    let msg = err.to_string();
    assert!(msg.contains("never fired"), "unexpected error: {msg}");
    let _ = std::fs::remove_dir_all(&cfg.dir);
}
