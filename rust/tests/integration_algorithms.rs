//! Property-based invariant suite over EVERY algorithm in the registry
//! (DESIGN.md §6), driven by the `testkit` framework with cluster-script
//! generation + shrinking.

use memento::algorithms::{self, ConsistentHasher, Memento, RemovalOrder, ALL_ALGOS, PAPER_ALGOS};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::simulator::{audit, scenario};
use memento::testkit::script::{replay, Script};
use memento::testkit::{forall_noshrink, Config};

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

fn build(name: &str, w: usize) -> Box<dyn ConsistentHasher> {
    algorithms::by_name(name, w, w * 10).unwrap()
}

/// Invariant 1 — totality & termination: any add/remove script leaves every
/// key mapping to a *working* bucket (also exercises Prop. VI.2: the
/// lookup always terminates — a violation would hang the test).
#[test]
fn prop_lookup_total_after_any_script() {
    let probe = keys(300, 0xAB);
    for name in ALL_ALGOS {
        forall_noshrink(
            &format!("totality/{name}"),
            Config::with_cases(40),
            |rng| Script::generate(rng, 64, 40),
            |script| {
                let mut algo = build(name, script.initial as usize);
                replay(algo.as_mut(), script, |a, _op| {
                    for &k in &probe {
                        let b = a.lookup(k);
                        if !a.is_working(b) {
                            return Err(format!("{name}: key {k:#x} -> non-working {b}"));
                        }
                    }
                    Ok(())
                })
            },
        );
    }
}

/// Invariant 2 — minimal disruption on removal (strict algorithms).
#[test]
fn prop_minimal_disruption_on_removal() {
    let probe = keys(4_000, 0xCD);
    for name in ALL_ALGOS {
        forall_noshrink(
            &format!("disruption/{name}"),
            Config::with_cases(25),
            |rng| (2 + rng.next_below(60) as u32, rng.next_u64()),
            |&(w, pick)| {
                let mut algo = build(name, w as usize);
                let strict = algo.strict_disruption();
                let before: Vec<u32> = probe.iter().map(|k| algo.lookup(*k)).collect();
                let wb = algo.working_buckets();
                let victim = wb[(pick as usize) % wb.len()];
                if algo.remove(victim).is_err() {
                    return Ok(()); // e.g. Jump non-tail: rejection is the contract
                }
                let after: Vec<u32> = probe.iter().map(|k| algo.lookup(*k)).collect();
                let rep = audit::disruption(&before, &after, &probe, &[victim]);
                if strict && rep.collateral > 0 {
                    return Err(format!(
                        "{name}: {} collateral moves removing {victim} from w={w}",
                        rep.collateral
                    ));
                }
                // Non-strict (Maglev): the Maglev paper reports ~1% churn
                // at m/w ≈ 100 for production sizes; tiny clusters (w ≤ 10)
                // see higher variance, so the gate is 12%.
                if !strict && rep.collateral_frac() > 0.12 {
                    return Err(format!(
                        "{name}: collateral churn {:.3} exceeds bound",
                        rep.collateral_frac()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// Invariant 3 — monotonicity on add: keys move only TO the new bucket
/// (strict algorithms), and roughly k/(w+1) of them for the paper's four.
#[test]
fn prop_monotonicity_on_add() {
    let probe = keys(6_000, 0xEF);
    for name in ALL_ALGOS {
        forall_noshrink(
            &format!("monotonicity/{name}"),
            Config::with_cases(20),
            |rng| Script::generate(rng, 40, 16),
            |script| {
                let mut algo = build(name, script.initial as usize);
                replay(algo.as_mut(), script, |_a, _op| Ok(()))?;
                let strict = algo.strict_disruption();
                let rep = match audit::monotonicity(algo.as_mut(), &probe) {
                    Ok(r) => r,
                    Err(_) => return Ok(()), // capacity exhausted: contract
                };
                if strict && rep.moved_elsewhere > 0 {
                    return Err(format!(
                        "{name}: {} keys moved between surviving buckets",
                        rep.moved_elsewhere
                    ));
                }
                Ok(())
            },
        );
    }
}

/// Invariant 4 — balance: χ² within normal bounds for the paper's four
/// algorithms after arbitrary removal patterns.
#[test]
fn prop_balance_under_removals() {
    let probe = keys(120_000, 0x11);
    for name in PAPER_ALGOS {
        forall_noshrink(
            &format!("balance/{name}"),
            Config::with_cases(6),
            |rng| (10 + rng.next_below(40) as u32, rng.next_u64(), rng.next_below(30)),
            |&(w, seed, removals)| {
                let mut algo = build(name, w as usize);
                let mut rng = Xoshiro256::new(seed);
                scenario::apply_removals(
                    algo.as_mut(),
                    (removals as usize).min(w as usize / 2),
                    RemovalOrder::Random,
                    &mut rng,
                );
                let rep = audit::balance(algo.as_ref(), &probe);
                // 6σ χ² gate + a coarse per-bucket deviation ceiling.
                if !rep.is_uniform(6.0) {
                    return Err(format!(
                        "{name}: chi2 {:.1} (dof {}) after {} removals from {w}",
                        rep.chi2, rep.dof, removals
                    ));
                }
                if rep.max_deviation > 0.25 {
                    return Err(format!("{name}: max deviation {:.3}", rep.max_deviation));
                }
                Ok(())
            },
        );
    }
}

/// Invariant 4b — weighted balance: under the bucket-set construction
/// (a node of weight w owns w buckets; DESIGN.md §10), each node's key
/// share is proportional to its weight. Per-bucket balance (invariant 4)
/// lifts to per-node balance by summation; this pins the composition for
/// Memento, Anchor and Dx across random weight vectors.
#[test]
fn prop_weighted_balance_share_proportional_to_weight() {
    let probe = keys(120_000, 0x77);
    for name in ["memento", "anchor", "dx"] {
        forall_noshrink(
            &format!("weighted-balance/{name}"),
            Config::with_cases(6),
            |rng| (2 + rng.next_below(6) as usize, rng.next_u64()),
            |&(nodes, seed)| {
                let mut rng = Xoshiro256::new(seed);
                let weights: Vec<usize> =
                    (0..nodes).map(|_| 1 + rng.next_below(5) as usize).collect();
                let total: usize = weights.iter().sum();
                let algo = build(name, total);
                // bucket → owning node, contiguous weight-sized ranges.
                let mut owner = Vec::with_capacity(total);
                for (i, w) in weights.iter().enumerate() {
                    for _ in 0..*w {
                        owner.push(i);
                    }
                }
                let mut counts = vec![0usize; nodes];
                for &k in &probe {
                    counts[owner[algo.lookup(k) as usize]] += 1;
                }
                for i in 0..nodes {
                    let share = counts[i] as f64 / probe.len() as f64;
                    let want = weights[i] as f64 / total as f64;
                    let rel = (share - want).abs() / want;
                    if rel > 0.10 {
                        return Err(format!(
                            "{name}: node {i} (w={} of {total}) share {share:.4}, \
                             want {want:.4} (rel err {rel:.3})",
                            weights[i]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Invariant 5 — LIFO equivalence: under tail-only churn Memento IS Jump,
/// with an empty replacement set and Θ(1)-equivalent memory.
#[test]
fn prop_lifo_equivalence() {
    forall_noshrink(
        "memento≡jump under LIFO",
        Config::with_cases(40),
        |rng| (1 + rng.next_below(100) as u32, rng.next_below(40) as u32, rng.next_u64()),
        |&(w, churn, seed)| {
            let mut m = Memento::new(w as usize);
            let mut j = algorithms::jump::Jump::new(w as usize);
            let mut rng = Xoshiro256::new(seed);
            for _ in 0..churn {
                if rng.next_bool(0.5) && m.working() > 1 {
                    let tail = (m.size() - 1) as u32;
                    m.remove(tail).unwrap();
                    j.remove(tail).unwrap();
                } else {
                    m.add().unwrap();
                    j.add().unwrap();
                }
            }
            if m.removed() != 0 {
                return Err("LIFO churn populated R".into());
            }
            for k in keys(200, seed).iter() {
                if m.lookup(*k) != j.lookup(*k) {
                    return Err(format!("divergence at key {k:#x}"));
                }
            }
            Ok(())
        },
    );
}

/// Invariant 6 — restore order: after arbitrary removals, repeated add()
/// returns removed buckets LIFO and fully untangles the chains.
#[test]
fn prop_restore_untangles_chains() {
    forall_noshrink(
        "memento restore order",
        Config::with_cases(60),
        |rng| (2 + rng.next_below(64) as u32, rng.next_u64()),
        |&(w, seed)| {
            let mut m = Memento::new(w as usize);
            let mut rng = Xoshiro256::new(seed);
            let removed = scenario::apply_removals(
                &mut m,
                (w as usize).saturating_sub(1).min(rng.next_below(w as u64) as usize),
                RemovalOrder::Random,
                &mut rng,
            );
            // Restore all: must come back in exact reverse order.
            for expect in removed.iter().rev() {
                let got = m.add().map_err(|e| e.to_string())?;
                if got != *expect {
                    return Err(format!("restored {got}, expected {expect}"));
                }
            }
            if m.removed() != 0 || m.working() != w as usize {
                return Err("cluster not fully restored".into());
            }
            Ok(())
        },
    );
}

/// Invariant 8 — memory law: Memento Θ(r) vs Anchor/Dx Θ(a) (exact bytes).
#[test]
fn prop_memory_laws() {
    forall_noshrink(
        "memory Θ-laws",
        Config::with_cases(12),
        |rng| (64 + rng.next_below(2000) as usize, rng.next_u64()),
        |&(w, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let mut mem = Memento::new(w);
            let anchor = algorithms::anchor::Anchor::new(w * 10, w);
            let dx = algorithms::dx::Dx::new(w * 10, w);
            let mem_before = mem.state_bytes();
            scenario::apply_removals(&mut mem, w / 4, RemovalOrder::Random, &mut rng);
            let mem_after = mem.state_bytes();
            // Memento grows with r…
            if mem_after <= mem_before && w / 4 > 8 {
                return Err("memento state did not grow with removals".into());
            }
            // …but stays well under the Θ(a) structures at a/w=10.
            if mem_after >= anchor.state_bytes() {
                return Err(format!(
                    "memento {} ≥ anchor {} at w={w}",
                    mem_after,
                    anchor.state_bytes()
                ));
            }
            // Dx is Θ(a) bits: must exceed memento's empty state for big a.
            if w > 500 && dx.state_bytes() < w / 8 {
                return Err("dx bit array smaller than a/8 bytes?".into());
            }
            Ok(())
        },
    );
}

/// Cross-check: every algorithm's working_buckets() agrees with
/// is_working() and with the lookup image.
#[test]
fn prop_working_set_consistency() {
    for name in ALL_ALGOS {
        forall_noshrink(
            &format!("working-set/{name}"),
            Config::with_cases(20),
            |rng| Script::generate(rng, 32, 24),
            |script| {
                let mut algo = build(name, script.initial as usize);
                replay(algo.as_mut(), script, |a, _op| {
                    let wb = a.working_buckets();
                    if wb.len() != a.working() {
                        return Err(format!("{name}: |working_buckets| != working()"));
                    }
                    if wb.windows(2).any(|p| p[0] >= p[1]) {
                        return Err(format!("{name}: working_buckets not ascending"));
                    }
                    for &b in &wb {
                        if !a.is_working(b) {
                            return Err(format!("{name}: {b} listed but not working"));
                        }
                    }
                    Ok(())
                })
            },
        );
    }
}
