//! Integration: the scenario driver reproduces the paper's *qualitative*
//! claims at CI scale — who wins, where the crossovers sit, how memory
//! scales. Absolute nanoseconds are hardware-dependent; shapes are not.

use memento::algorithms::RemovalOrder;
use memento::benchkit::BenchConfig;
use memento::simulator::scenario::{self, ScenarioConfig};
use std::time::Duration;

fn cfg() -> ScenarioConfig {
    ScenarioConfig {
        keys: 20_000,
        bench: BenchConfig {
            warmup: Duration::from_millis(30),
            samples: 10,
            target_sample_time: Duration::from_millis(1),
            max_total: Duration::from_millis(600),
        },
        ..Default::default()
    }
}

/// Fig. 18's shape: stable-cluster memory — Jump < Memento ≪ Dx < Anchor.
#[test]
fn stable_memory_ordering() {
    let cfg = cfg();
    for w in [1_000usize, 10_000] {
        let jump = scenario::stable_cell("jump", w, &cfg).state_bytes;
        let memento = scenario::stable_cell("memento", w, &cfg).state_bytes;
        let dx = scenario::stable_cell("dx", w, &cfg).state_bytes;
        let anchor = scenario::stable_cell("anchor", w, &cfg).state_bytes;
        assert!(jump <= memento, "w={w}");
        assert!(memento < dx, "w={w}: memento {memento} !< dx {dx}");
        assert!(dx < anchor, "w={w}: dx {dx} !< anchor {anchor}");
        // Memento's stable-state memory must be O(1)-ish (empty map).
        assert!(memento < 1_000, "w={w}: stable memento state {memento} bytes");
    }
}

/// Fig. 17's *robust* shape: stable lookups — Memento ≈ Jump ("nearly
/// identical performance", §V) at every size.
///
/// Deviation note (EXPERIMENTS.md §Deviations): the paper also shows Dx
/// slowest in the stable scenario; that ordering is an artifact of the
/// authors' Java Dx (per-lookup allocations). Our optimized Dx does
/// E[a/w]=10 ~3ns probes and legitimately beats the ~ln(n) f64-division
/// jump walk at a/w = 10 — its weakness appears exactly where Table I
/// says: lookups grow linearly in a/w (sensitivity test below) while
/// Memento stays flat.
#[test]
fn stable_lookup_ordering() {
    let cfg = cfg();
    for w in [100usize, 10_000] {
        let jump = scenario::stable_cell("jump", w, &cfg).lookup.median_ns;
        let memento = scenario::stable_cell("memento", w, &cfg).lookup.median_ns;
        assert!(
            memento < jump * 1.5,
            "w={w}: memento {memento:.0}ns !≈ jump {jump:.0}ns"
        );
    }
}

/// Fig. 19/20's shape: one-shot 90% removals — LIFO keeps Memento at
/// Jump-level memory; random removals grow it with r but keep it below
/// Anchor (Θ(a) with a = 10w).
#[test]
fn oneshot_memory_shapes() {
    let cfg = cfg();
    let w = 5_000;
    let best = scenario::oneshot_cell("memento", w, 0.9, RemovalOrder::Lifo, &cfg);
    let worst = scenario::oneshot_cell("memento", w, 0.9, RemovalOrder::Random, &cfg);
    let anchor = scenario::oneshot_cell("anchor", w, 0.9, RemovalOrder::Random, &cfg);
    assert!(best.state_bytes < 1_000, "LIFO removals must not grow R");
    assert!(worst.state_bytes > best.state_bytes * 10);
    assert!(worst.state_bytes < anchor.state_bytes);
    assert_eq!(best.working, 500);
    assert_eq!(worst.working, 500);
}

/// Fig. 23's shape (best case / LIFO): Memento stays at Jump speed (the
/// replacement set stays EMPTY under LIFO churn) while Dx degrades badly
/// as the working set shrinks against its fixed capacity — "Dx is by far
/// the worst performer" (§VIII-D).
#[test]
fn incremental_lookup_shape() {
    let cfg = cfg();
    let w = 20_000;
    let fr = &[0.2, 0.9];
    let memento = scenario::incremental_cells("memento", w, fr, RemovalOrder::Lifo, &cfg);
    let dx = scenario::incremental_cells("dx", w, fr, RemovalOrder::Lifo, &cfg);
    // Memento under LIFO keeps R empty: memory flat & tiny.
    assert!(memento[1].state_bytes < 1_000, "LIFO must keep R empty");
    // Dx at 90% removed probes ~a/w_live = 100 slots: far slower than
    // memento (which is just jump over the shrunken b-array).
    assert!(
        dx[1].lookup.median_ns > memento[1].lookup.median_ns * 2.0,
        "90% LIFO: dx {:.0}ns !≫ memento {:.0}ns",
        dx[1].lookup.median_ns,
        memento[1].lookup.median_ns
    );
    // Dx degrades with the removal fraction; memento-LIFO does not (much).
    assert!(dx[1].lookup.median_ns > dx[0].lookup.median_ns * 2.0);

    // Fig. 24 (worst case / random): memento's ln²(n/w) term shows up —
    // lookups at 90% removed are measurably slower than at 20%.
    let mw = scenario::incremental_cells(
        "memento",
        w,
        &[0.2, 0.9],
        RemovalOrder::Random,
        &cfg,
    );
    assert!(
        mw[1].lookup.median_ns > mw[0].lookup.median_ns * 1.3,
        "degradation with removals missing: {:.0} vs {:.0}",
        mw[1].lookup.median_ns,
        mw[0].lookup.median_ns
    );
}

/// §VIII-E's shape: Dx lookup grows ~linearly with a/w, Anchor's memory
/// grows linearly, Memento is flat (independent of the ratio).
#[test]
fn sensitivity_shapes() {
    let cfg = cfg();
    let w = 2_000;
    let dx5 = scenario::sensitivity_cell("dx", w, 5, 0.2, &cfg);
    let dx50 = scenario::sensitivity_cell("dx", w, 50, 0.2, &cfg);
    assert!(
        dx50.lookup.median_ns > dx5.lookup.median_ns * 3.0,
        "dx lookup must degrade with ratio: {:.0} vs {:.0}",
        dx50.lookup.median_ns,
        dx5.lookup.median_ns
    );
    let an5 = scenario::sensitivity_cell("anchor", w, 5, 0.2, &cfg);
    let an50 = scenario::sensitivity_cell("anchor", w, 50, 0.2, &cfg);
    assert!(an50.state_bytes > an5.state_bytes * 8, "anchor memory must scale with a");

    let m5 = scenario::sensitivity_cell("memento", w, 5, 0.2, &cfg);
    let m50 = scenario::sensitivity_cell("memento", w, 50, 0.2, &cfg);
    assert_eq!(m5.state_bytes, m50.state_bytes, "memento is ratio-independent");
}

/// Table I empirics: Memento's traced outer-loop iterations stay within
/// the Prop. VII.1 bound E[τ] ≤ 1 + ln(n/w) (with slack for variance).
#[test]
fn table1_outer_loop_bound() {
    use memento::algorithms::ConsistentHasher;
    use memento::hashing::prng::{Rng64, Xoshiro256};
    let cfg = cfg();
    let mut rng = Xoshiro256::new(42);
    for (w, frac) in [(2_000usize, 0.5), (2_000, 0.9), (10_000, 0.65)] {
        let mut m = memento::algorithms::Memento::new(w);
        scenario::apply_removals(
            &mut m,
            (w as f64 * frac) as usize,
            RemovalOrder::Random,
            &mut rng,
        );
        let n = m.size() as f64;
        let ww = m.working() as f64;
        let bound = 1.0 + (n / ww).ln();
        let trials = 20_000;
        let mut total_outer = 0u64;
        for _ in 0..trials {
            total_outer += m.lookup_traced(rng.next_u64()).outer_iters as u64;
        }
        let mean = total_outer as f64 / trials as f64;
        assert!(
            mean <= bound * 1.15,
            "w={w} frac={frac}: mean outer iters {mean:.2} > bound {bound:.2}"
        );
    }
    let _ = cfg;
}
