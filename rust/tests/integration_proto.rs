//! Protocol integration: codec equivalence proven by round-trip over
//! every `Request`/`Response` variant, text-vs-binary agreement against
//! a live server, and malformed-frame handling at the wire (typed
//! rejects, no worker hang, connections that cannot resync get closed).

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::netserver::{Client, ClientError};
use memento::proto::{
    self, encode_frame, try_frame, ErrCode, ProtoError, Request, Response, MAGIC_BINARY,
    MAX_FRAME_LEN,
};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn start(max_conns: usize) -> (Arc<Service>, memento::netserver::ServerHandle) {
    let router = Router::new("memento", 8, 80, None).expect("router");
    let svc = Service::new(router);
    let server = svc.serve("127.0.0.1:0", max_conns).expect("bind");
    (svc, server)
}

/// Every request variant, with edge payloads on the hot commands.
fn all_requests() -> Vec<Request> {
    vec![
        Request::Lookup { key: 0 },
        Request::Lookup { key: u64::MAX },
        Request::LookupBatch { keys: vec![7] },
        Request::LookupBatch { keys: (0..1000).collect() },
        Request::Get { key: 1 },
        Request::Put { key: u64::MAX, value: "v".repeat(512) },
        Request::Kill { bucket: 3 },
        Request::KillNode { node: 5 },
        Request::Add,
        Request::AddWeighted { weight: 4 },
        Request::SetWeight { node: 2, weight: 9 },
        Request::Nodes,
        Request::MStat,
        Request::Stats,
        Request::Epoch,
        Request::Fsync,
        Request::WalStat,
        Request::Compact,
        Request::Recover,
        Request::Metrics,
        Request::MSample,
        Request::Series { metric: "service_requests".into() },
        Request::Stages,
        Request::CacheStat,
        Request::Ping,
        Request::Dump { max: Some(16) },
        Request::Dump { max: None },
    ]
}

#[test]
fn every_request_variant_round_trips_both_codecs() {
    for req in all_requests() {
        let line = req.render_text();
        assert_eq!(
            Request::parse_text(&line).unwrap(),
            req,
            "text round trip must be identity for {line:?}"
        );
        for crc in [false, true] {
            let frame = req.encode_binary(crc);
            let (op, payload, consumed) =
                try_frame(&frame, crc).unwrap().expect("one complete frame");
            assert_eq!(consumed, frame.len());
            assert_eq!(
                Request::decode_binary(op, &payload).unwrap(),
                req,
                "binary round trip (crc={crc}) must be identity for {line:?}"
            );
        }
    }
}

#[test]
fn every_response_variant_round_trips_both_codecs() {
    let responses = vec![
        Response::Bucket { bucket: 0, node: "node-0".into() },
        Response::Bucket { bucket: u32::MAX, node: "node-17".into() },
        Response::Buckets((0..1000).collect()),
        Response::Ok { node: "node-3".into() },
        Response::Value { node: "node-1".into(), value: "payload-42".into() },
        Response::Missing { node: "node-9".into() },
        Response::Info("EPOCH 3 WORKING 4".into()),
        Response::Body("# line one\n# line two\n# EOF".into()),
    ];
    for resp in responses {
        let payload = resp.render_text();
        assert_eq!(
            Response::parse_text(&payload).unwrap(),
            resp,
            "text round trip must be identity for {payload:?}"
        );
        for crc in [false, true] {
            let frame = resp.encode_binary(crc);
            let (op, body, consumed) =
                try_frame(&frame, crc).unwrap().expect("one complete frame");
            assert_eq!(consumed, frame.len());
            assert_eq!(
                Response::decode_binary(op, &body).unwrap(),
                resp,
                "binary round trip (crc={crc}) must be identity"
            );
        }
    }
    // An empty bucket list renders as a bare `BUCKETS` token, which the
    // lenient text classifier reads back as Info — the binary codec is
    // the one that carries it losslessly.
    let empty = Response::Buckets(vec![]);
    let frame = empty.encode_binary(false);
    let (op, body, _) = try_frame(&frame, false).unwrap().unwrap();
    assert_eq!(Response::decode_binary(op, &body).unwrap(), empty);
}

#[test]
fn proto_errors_round_trip_both_codecs() {
    let errors = vec![
        ProtoError::parse("LOOKUP needs a key"),
        ProtoError::unknown_cmd("FROB"),
        ProtoError::bad_frame("frame length 99999999 exceeds max"),
        ProtoError::refused("unknown node node-99"),
        ProtoError::unavailable("this service did not start from recovery"),
        ProtoError { code: ErrCode::Internal, msg: "anything else".into() },
    ];
    for err in errors {
        let line = err.render_text();
        match Response::parse_text(&line) {
            Err(back) => assert_eq!(back, err, "text round trip must be identity for {line:?}"),
            Ok(r) => panic!("ERR line {line:?} parsed as a success response {r:?}"),
        }
        for crc in [false, true] {
            let frame = err.encode_binary(crc);
            let (op, body, _) = try_frame(&frame, crc).unwrap().expect("one complete frame");
            match Response::decode_binary(op, &body) {
                Err(back) => assert_eq!(back, err, "binary round trip (crc={crc})"),
                Ok(r) => panic!("ERR frame decoded as a success response {r:?}"),
            }
        }
    }
}

#[test]
fn text_and_binary_clients_agree_against_a_live_server() {
    let (_svc, server) = start(16);
    let mut text = Client::connect(&server.addr()).unwrap();
    let mut bin = Client::connect_binary(&server.addr()).unwrap();
    let mut bin_crc = Client::connect_binary_crc(&server.addr()).unwrap();

    let key = proto::digest_key("user:42");
    let reqs = vec![
        Request::Put { key, value: "alice".into() },
        Request::Get { key },
        Request::Lookup { key },
        Request::LookupBatch { keys: vec![1, 2, 3, key] },
        Request::Get { key: proto::digest_key("missing-key") },
        Request::Epoch,
        Request::MStat,
        Request::Nodes,
        Request::WalStat,
        Request::Stages,
        Request::Metrics,
        Request::Recover,
        Request::Series { metric: "no_such_metric".into() },
    ];
    for req in reqs {
        let label = req.render_text();
        let a = text.call(&req);
        let b = bin.call(&req);
        let c = bin_crc.call(&req);
        match (&a, &b, &c) {
            (Ok(ra), Ok(rb), Ok(rc)) => {
                // Counters move between calls, so only the stable
                // responses are compared byte-for-byte; the rest must
                // agree on shape.
                assert_eq!(
                    std::mem::discriminant(ra),
                    std::mem::discriminant(rb),
                    "text and binary disagree on shape for {label:?}"
                );
                assert_eq!(
                    std::mem::discriminant(rb),
                    std::mem::discriminant(rc),
                    "crc and plain binary disagree on shape for {label:?}"
                );
                if req.is_data_path() {
                    assert_eq!(ra, rb, "data-path responses must be identical for {label:?}");
                    assert_eq!(rb, rc, "data-path responses must be identical for {label:?}");
                }
            }
            (
                Err(ClientError::Proto(ea)),
                Err(ClientError::Proto(eb)),
                Err(ClientError::Proto(ec)),
            ) => {
                assert_eq!(ea, eb, "typed errors must agree for {label:?}");
                assert_eq!(eb, ec, "typed errors must agree for {label:?}");
            }
            _ => panic!("transports disagree on {label:?}: {a:?} vs {b:?} vs {c:?}"),
        }
    }
    drop((text, bin, bin_crc));
    server.shutdown();
}

/// Read everything the server sends until EOF or timeout.
fn drain(stream: &mut TcpStream) -> Vec<u8> {
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut out = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    out
}

/// Decode exactly one complete frame from the front of `buf`.
fn first_frame(buf: &[u8]) -> (u8, Vec<u8>) {
    let (op, payload, _) = try_frame(buf, false)
        .expect("server reply must be well-framed")
        .expect("server reply must be complete");
    (op, payload)
}

#[test]
fn oversized_length_prefix_gets_a_typed_reject_and_a_close() {
    let (_svc, server) = start(16);
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    raw.write_all(&[MAGIC_BINARY]).unwrap();
    let huge = (MAX_FRAME_LEN as u32) + 1;
    raw.write_all(&huge.to_le_bytes()).unwrap();
    raw.write_all(b"garbage-that-should-never-be-read").unwrap();

    let reply = drain(&mut raw);
    let (op, payload) = first_frame(&reply);
    match Response::decode_binary(op, &payload) {
        Err(e) => assert_eq!(e.code, ErrCode::BadFrame, "oversized frame must reject as {e:?}"),
        Ok(r) => panic!("oversized frame got a success response {r:?}"),
    }
    // drain() hit EOF, so the server closed the unresyncable connection.

    // The server is still fully functional for new connections.
    let mut c = Client::connect_binary(&server.addr()).unwrap();
    assert!(c.call(&Request::Lookup { key: 9 }).is_ok());
    drop(c);
    server.shutdown();
}

#[test]
fn unknown_opcode_rejects_but_the_connection_survives() {
    let (_svc, server) = start(16);
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    raw.write_all(&[MAGIC_BINARY]).unwrap();
    // Well-framed, meaningless opcode: a parse-level reject, not a
    // framing violation — the connection must stay open.
    raw.write_all(&encode_frame(0x7A, b"x", false)).unwrap();
    // Pipeline a valid request behind it to prove resync.
    raw.write_all(&Request::Lookup { key: 3 }.encode_binary(false)).unwrap();

    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut frames = Vec::new();
    while frames.len() < 2 {
        let n = raw.read(&mut chunk).expect("server must answer both frames");
        assert!(n > 0, "server closed a connection it should have kept");
        buf.extend_from_slice(&chunk[..n]);
        while let Some((op, payload, consumed)) = try_frame(&buf, false).unwrap() {
            buf.drain(..consumed);
            frames.push((op, payload));
        }
    }
    match Response::decode_binary(frames[0].0, &frames[0].1) {
        Err(e) => assert_eq!(e.code, ErrCode::BadFrame, "unknown opcode must reject as {e:?}"),
        Ok(r) => panic!("unknown opcode got a success response {r:?}"),
    }
    match Response::decode_binary(frames[1].0, &frames[1].1) {
        Ok(Response::Bucket { .. }) => {}
        other => panic!("valid request after a reject must still answer, got {other:?}"),
    }
    drop(raw);
    server.shutdown();
}

#[test]
fn torn_mid_frame_disconnects_leave_the_worker_pool_healthy() {
    let (_svc, server) = start(64);
    // A wave of connections that each die mid-frame: magic, a length
    // prefix promising more than they send, then an abrupt close.
    for i in 0..16u32 {
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&[MAGIC_BINARY]).unwrap();
        raw.write_all(&64u32.to_le_bytes()).unwrap();
        raw.write_all(&i.to_le_bytes()).unwrap();
        drop(raw);
    }
    // A second wave that die mid-length-prefix.
    for _ in 0..16 {
        let mut raw = TcpStream::connect(server.addr()).unwrap();
        raw.write_all(&[MAGIC_BINARY, 0x10]).unwrap();
        drop(raw);
    }
    // No worker may be stuck waiting on those torn frames: a normal
    // client gets 100 prompt answers.
    let mut c = Client::connect_binary(&server.addr()).unwrap();
    for key in 0..100 {
        match c.call(&Request::Lookup { key }) {
            Ok(Response::Bucket { .. }) => {}
            other => panic!("lookup {key} failed after torn-frame wave: {other:?}"),
        }
    }
    drop(c);
    let remaining = server.shutdown();
    assert_eq!(remaining, 0, "torn connections must not linger past shutdown drain");
}
