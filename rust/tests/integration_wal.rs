//! Integration: the durability layer end to end — WAL-backed service
//! restarts, recovery idempotence (double recovery is byte-identical;
//! a fully-applied plan replays as a no-op), torn-tail repair at both
//! log levels, and post-recovery planner-delta coverage.

use memento::coordinator::migration::{MigrationConfig, MigrationPlan, Migrator, PlanKind};
use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::coordinator::storage::StorageCluster;
use memento::coordinator::wal::{CoordinatorWal, DurabilityConfig, StorageDurability};
use memento::metrics::WalMetrics;
use memento::netserver::{Client, ClientError};
use memento::proto::Request;
use memento::simulator::audit;
use std::io::Write as _;
use std::sync::Arc;

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so assertions stay line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("memento-itwal-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Full stack: a durable service over TCP, killed (dropped) and
/// recovered into a second server. Every write acked on the first
/// incarnation must be readable on the second.
#[test]
fn durable_service_survives_a_restart_over_tcp() {
    let dir = scratch("tcp-restart");
    let durability = DurabilityConfig::new(&dir);
    {
        let router = Router::new("memento", 6, 96, None).unwrap();
        let svc =
            Service::durable(router, 2, MigrationConfig::default(), &durability).unwrap();
        let server = svc.serve("127.0.0.1:0", 16).unwrap();
        let mut c = Client::connect(&server.addr()).unwrap();
        for i in 0..400 {
            let r = req(&mut c, &format!("PUT rk{i} rv{i}"));
            assert!(r.starts_with("OK"), "{r}");
        }
        let r = req(&mut c, "FSYNC");
        assert!(r.starts_with("SYNCED"), "{r}");
        drop(c);
        server.shutdown();
    }
    let (svc, report) =
        Service::recover(&durability, 2, MigrationConfig::default()).unwrap();
    assert_eq!(report.epoch, 0, "no admin change ran");
    assert!(report.replay.wal_records >= 400, "{:?}", report.replay);
    assert!(report.plans.is_empty());
    let server = svc.serve("127.0.0.1:0", 16).unwrap();
    let mut c = Client::connect(&server.addr()).unwrap();
    for i in 0..400 {
        let r = req(&mut c, &format!("GET rk{i}"));
        assert!(r.contains(&format!("rv{i}")), "rk{i} lost across restart: {r}");
    }
    drop(c);
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Recovery idempotence: recovering, then recovering the recovered
/// state again, reproduces byte-identical per-node content (digests)
/// and finds nothing left to do — no pending plans, zero reconcile
/// moves, zero torn tails.
#[test]
fn double_recovery_is_byte_identical_and_a_noop() {
    let dir = scratch("double-recovery");
    let durability = DurabilityConfig::new(&dir);
    {
        // Manual migration mode: KILLN logs the epoch + plan records but
        // parks the plan, simulating a crash before the drain ran.
        let router = Router::new("memento", 6, 96, None).unwrap();
        let svc = Service::durable(
            router,
            1,
            MigrationConfig { auto: false, ..MigrationConfig::default() },
            &durability,
        )
        .unwrap();
        for i in 0..500 {
            let r = svc.handle(&format!("PUT dk{i} dv{i}"));
            assert!(r.starts_with("OK"), "{r}");
        }
        let r = svc.handle("KILLN node-2");
        assert!(r.starts_with("KILLED"), "{r}");
    }
    let digests_first = {
        let (svc, report) =
            Service::recover(&durability, 1, MigrationConfig::default()).unwrap();
        assert_eq!(report.plans.len(), 1, "the parked drain must be pending");
        assert!(report.plan_moved > 0, "replay must drain the dead node");
        for i in 0..500 {
            let r = svc.handle(&format!("GET dk{i}"));
            assert!(r.contains(&format!("dv{i}")), "dk{i}: {r}");
        }
        let mut d: Vec<(u64, u64)> =
            svc.storage.nodes().iter().map(|(id, n)| (id.0, n.content_digest())).collect();
        d.sort_unstable();
        d
    };
    let (svc, report) =
        Service::recover(&durability, 1, MigrationConfig::default()).unwrap();
    assert!(report.plans.is_empty(), "the replayed plan was retired by PlanEnd");
    assert_eq!(report.plan_moved, 0);
    assert_eq!(report.reconciled, 0, "second recovery must find nothing misplaced");
    assert_eq!(report.replay.torn_tails, 0);
    let mut digests_second: Vec<(u64, u64)> =
        svc.storage.nodes().iter().map(|(id, n)| (id.0, n.content_digest())).collect();
    digests_second.sort_unstable();
    assert_eq!(digests_first, digests_second, "double recovery must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The copy-install-remove invariant's replay face: a plan that already
/// ran to completion — but whose `PlanEnd` never reached the control
/// log — is re-executed in full by recovery and moves nothing. The
/// recovered placement still covers every observed mover
/// (`recovery_coverage` missed == 0).
#[test]
fn fully_applied_plan_replays_as_a_noop() {
    let dir = scratch("applied-plan-replay");
    let keys: Vec<u64> = (0..4_000u64).map(memento::hashing::mix::splitmix64_mix).collect();
    {
        // Assemble the durable pieces by hand so the executor runs
        // WITHOUT the coordinator log: the plan fully applies, but no
        // PlanEnd record exists — exactly a crash in finish_plan.
        let metrics = Arc::new(WalMetrics::new());
        let (cwal, state) = CoordinatorWal::open(&dir, metrics.clone()).unwrap();
        assert!(state.epoch.is_none());
        let router = Router::new("memento", 8, 128, None).unwrap();
        let (storage, _stats) = StorageCluster::durable(StorageDurability {
            root: dir.clone(),
            opts: Default::default(),
            metrics,
        })
        .unwrap();
        let storage = Arc::new(storage);
        for &k in &keys {
            let (_b, n) = router.route(k);
            storage.node(n).put(k, k.to_le_bytes().to_vec());
        }
        let (victim, seed) = router.fail_bucket_planned(3).unwrap();
        let (memento, membership) = router.durable_state().unwrap();
        cwal.log_epoch(&memento, &membership);
        let plan = MigrationPlan::from_seed(PlanKind::Drain, victim, seed);
        assert!(cwal.log_plan_begin(&plan), "memento plans must serialize");
        let migrator = Migrator::spawn(
            router.clone(),
            storage.clone(),
            MigrationConfig { auto: false, ..MigrationConfig::default() },
        );
        migrator.enqueue(plan);
        let moved = migrator.run_pending();
        assert!(moved > 0, "the drain must move the victim's records");
        assert!(storage.node(victim).is_empty(), "drain must empty the dead node");
    }
    let (svc, report) = Service::recover(
        &DurabilityConfig::new(&dir),
        1,
        MigrationConfig { auto: false, ..MigrationConfig::default() },
    )
    .unwrap();
    assert_eq!(report.plans.len(), 1, "PlanBegin without PlanEnd must replay");
    assert_eq!(report.plan_moved, 0, "a fully-applied plan replays as a no-op");
    assert_eq!(report.reconciled, 0);
    for &k in &keys {
        let (_b, n) = svc.router.route(k);
        assert_eq!(
            svc.storage.node(n).get(k),
            Some(k.to_le_bytes().to_vec()),
            "key {k:#x} lost across the no-op replay"
        );
    }
    // Post-recovery delta coverage: the replayed plan's sources cover
    // every key that sits somewhere else than the old placement said.
    let plan = &report.plans[0];
    let sources: Vec<u32> = plan.sources.iter().map(|(b, _n)| *b).collect();
    let rep = svc.router.with_view(|algo, _m| {
        audit::recovery_coverage(&plan.old_memento, algo, &sources, plan.full_scan, &keys)
    });
    assert!(rep.moved > 0, "the kill moved tracer keys");
    assert_eq!(rep.missed, 0, "recovered placement strands no mover");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn tails at both log levels: garbage appended after the last valid
/// frame of the coordinator log and of a shard WAL is detected, counted
/// and truncated — and every acked (fsynced) write survives. A second
/// recovery sees a clean tail.
#[test]
fn torn_tails_are_repaired_at_both_log_levels() {
    let dir = scratch("torn-tails");
    let durability = DurabilityConfig::new(&dir);
    {
        let router = Router::new("memento", 5, 80, None).unwrap();
        let svc =
            Service::durable(router, 1, MigrationConfig::default(), &durability).unwrap();
        for i in 0..300 {
            let r = svc.handle(&format!("PUT tk{i} tv{i}"));
            assert!(r.starts_with("OK"), "{r}");
        }
        let r = svc.handle("FSYNC");
        assert!(r.starts_with("SYNCED"), "{r}");
    }
    // Tear the coordinator log's tail.
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(dir.join("coordinator.wal"))
        .unwrap();
    f.write_all(&[0xFF; 21]).unwrap();
    drop(f);
    // Tear the tail of the first shard WAL we can find.
    let node_dir = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| {
            p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.starts_with("node-"))
        })
        .expect("at least one node dir");
    let shard_wal = std::fs::read_dir(&node_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "wal"))
        .expect("at least one shard wal");
    let torn_len = std::fs::metadata(&shard_wal).unwrap().len() + 17;
    let mut f = std::fs::OpenOptions::new().append(true).open(&shard_wal).unwrap();
    f.write_all(&[0xFF; 17]).unwrap();
    drop(f);
    assert_eq!(std::fs::metadata(&shard_wal).unwrap().len(), torn_len);

    let (svc, report) =
        Service::recover(&durability, 1, MigrationConfig::default()).unwrap();
    assert!(report.replay.torn_tails >= 1, "{:?}", report.replay);
    assert!(report.replay.torn_bytes >= 17, "{:?}", report.replay);
    for i in 0..300 {
        let r = svc.handle(&format!("GET tk{i}"));
        assert!(r.contains(&format!("tv{i}")), "tk{i} lost to a torn tail: {r}");
    }
    assert!(
        std::fs::metadata(&shard_wal).unwrap().len() < torn_len,
        "open() must truncate the torn shard tail"
    );
    drop(svc);
    let (_svc, report) =
        Service::recover(&durability, 1, MigrationConfig::default()).unwrap();
    assert_eq!(report.replay.torn_tails, 0, "the repaired logs have clean tails");
    let _ = std::fs::remove_dir_all(&dir);
}
