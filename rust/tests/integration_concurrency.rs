//! Concurrency stress: the wait-free lookup path under continuous
//! membership churn, and the sharded storage under parallel clients.
//!
//! The torn-read assertion works because every router read runs against
//! one pinned [`memento::coordinator::router::RouterSnapshot`]: placement
//! and membership observed together at a single epoch. If publication
//! were torn (placement from one epoch, membership from another), a
//! looked-up bucket would be unbound or non-working, or two threads would
//! observe different placements for the same `(epoch, key)` pair.

use memento::coordinator::router::Router;
use memento::coordinator::storage::StorageNode;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Deterministic probe keys shared by every reader thread.
fn probe_keys(n: u64) -> Vec<u64> {
    (0..n).map(memento::hashing::mix::splitmix64_mix).collect()
}

#[test]
fn lookups_stay_consistent_under_continuous_kill_add_churn() {
    const CHURN_CYCLES: usize = 150;
    const READERS: usize = 4;
    let router = Router::new("memento", 16, 160, None).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let keys = Arc::new(probe_keys(64));

    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let router = router.clone();
            let stop = stop.clone();
            let keys = keys.clone();
            std::thread::spawn(move || {
                // For the first probe key, remember the bucket observed at
                // each epoch: placements are immutable per epoch, so every
                // observation of (epoch, key0) must agree — across reads
                // and across threads.
                let mut by_epoch: HashMap<u64, u32> = HashMap::new();
                let mut last_epoch = 0u64;
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    router.with_view(|a, m| {
                        let epoch = m.epoch();
                        assert!(epoch >= last_epoch, "epoch went backwards");
                        last_epoch = epoch;
                        for &k in keys.iter() {
                            let b = a.lookup(k);
                            assert!(a.is_working(b), "lookup returned a dead bucket");
                            assert!(
                                m.node_at(b).is_some(),
                                "torn read: bucket {b} unbound at epoch {epoch}"
                            );
                        }
                        let b0 = a.lookup(keys[0]);
                        match by_epoch.entry(epoch) {
                            std::collections::hash_map::Entry::Occupied(e) => {
                                assert_eq!(
                                    *e.get(),
                                    b0,
                                    "same epoch, different placement for key0"
                                );
                            }
                            std::collections::hash_map::Entry::Vacant(e) => {
                                e.insert(b0);
                            }
                        }
                    });
                    // The plain scalar path must hold the same invariant
                    // (its internal expect() panics on a torn read).
                    let (_b, _node) = router.route(keys[reads as usize % keys.len()]);
                    reads += 1;
                }
                (reads, by_epoch)
            })
        })
        .collect();

    // Churn: kill a working bucket, restore it, repeatedly. Single
    // injector thread, so every cycle is exactly two epochs.
    for _ in 0..CHURN_CYCLES {
        let wb = router.with_view(|a, _| a.working_buckets());
        let victim = wb[wb.len() / 2];
        router.fail_bucket(victim).expect("victim was working");
        router.add_node().expect("capacity available");
    }
    stop.store(true, Ordering::Relaxed);

    let mut merged: HashMap<u64, u32> = HashMap::new();
    let mut total_reads = 0u64;
    for r in readers {
        let (reads, by_epoch) = r.join().expect("a reader panicked (torn read)");
        total_reads += reads;
        for (epoch, b) in by_epoch {
            match merged.entry(epoch) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    assert_eq!(
                        *e.get(),
                        b,
                        "threads disagree on placement at epoch {epoch}"
                    );
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(b);
                }
            }
        }
    }
    assert!(total_reads > 0, "readers must have made progress");
    assert_eq!(
        router.epoch(),
        2 * CHURN_CYCLES as u64,
        "every kill/add cycle is exactly two published epochs"
    );
    assert_eq!(router.working(), 16, "cluster restored to full strength");
}

#[test]
fn concurrent_batched_and_scalar_readers_survive_churn() {
    // route_batch under churn: each batch runs against one snapshot, so
    // every returned bucket must have been working at some epoch — the
    // cheap invariant here is simply that nothing panics and bucket ids
    // stay inside the b-array across 60 epochs of churn.
    let router = Router::new("memento", 8, 80, None).unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let keys = Arc::new(probe_keys(256));
    let readers: Vec<_> = (0..3)
        .map(|_| {
            let router = router.clone();
            let stop = stop.clone();
            let keys = keys.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for b in router.route_batch(&keys) {
                        assert!(b < 8 + 64, "bucket id out of any possible range");
                    }
                }
            })
        })
        .collect();
    for _ in 0..30 {
        let wb = router.with_view(|a, _| a.working_buckets());
        router.fail_bucket(wb[0]).unwrap();
        router.add_node().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().expect("a batched reader panicked");
    }
}

#[test]
fn storage_shards_hold_under_parallel_writers() {
    const WRITERS: usize = 4;
    const PER_WRITER: u64 = 4_000;
    let node = Arc::new(StorageNode::default());
    let writers: Vec<_> = (0..WRITERS as u64)
        .map(|w| {
            let node = node.clone();
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    let k = w * PER_WRITER + i;
                    node.put(k, k.to_le_bytes().to_vec());
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    let total = (WRITERS as u64 * PER_WRITER) as usize;
    assert_eq!(node.len(), total, "no write lost across shards");
    let loads = node.shard_loads();
    assert_eq!(loads.iter().sum::<usize>(), total);
    let mean = total / StorageNode::SHARDS;
    for (i, l) in loads.iter().enumerate() {
        assert!(
            *l > mean / 2 && *l < mean * 2,
            "shard {i}: {l} records vs mean {mean} — keys not spread"
        );
    }
    // Every record readable with the right value.
    for k in (0..total as u64).step_by(97) {
        assert_eq!(node.get(k), Some(k.to_le_bytes().to_vec()));
    }
}
