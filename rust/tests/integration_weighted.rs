//! Weighted-membership acceptance (DESIGN.md §10): on a heterogeneous
//! cluster with weight skew ≥ 4:1 and replication = 2,
//!
//! * both copies of every key land on distinct **physical nodes** (not
//!   merely distinct buckets — a weighted node owns many buckets, and a
//!   bucket-distinct pair on one box dies together), and
//! * killing any single node loses zero acknowledged writes.
//!
//! Plus the protocol-level weighted lifecycle: `ADDW`-joined capacity
//! absorbs a weight-proportional key share end to end.

use memento::coordinator::membership::NodeId;
use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// Weights [4, 1, 1, 4, 2] over five nodes — skew 4:1, Σw = 12.
const WEIGHTS: [u32; 5] = [4, 1, 1, 4, 2];

fn weighted_service() -> (Arc<Router>, Arc<Service>, Vec<NodeId>) {
    let router = Router::new("memento", WEIGHTS.len(), 200, None).unwrap();
    let ids: Vec<NodeId> = (0..WEIGHTS.len() as u32)
        .map(|b| router.with_view(|_a, m| m.node_at(b)).unwrap())
        .collect();
    for (i, &w) in WEIGHTS.iter().enumerate() {
        if w > 1 {
            router.set_weight(ids[i], w).unwrap();
        }
    }
    let svc = Service::with_replicas(router.clone(), 2);
    (router, svc, ids)
}

#[test]
fn both_copies_of_every_key_land_on_distinct_physical_nodes() {
    let (router, svc, ids) = weighted_service();
    router.with_view(|a, m| {
        assert_eq!(a.working(), 12, "Σ weights buckets");
        assert_eq!(m.working_count(), 5, "5 physical nodes");
    });
    for i in 0..800 {
        let r = svc.handle(&format!("PUT wkey{i} wval{i}"));
        assert!(r.starts_with("OK "), "{r}");
    }
    for i in 0..800 {
        let key = Service::digest_key(&format!("wkey{i}"));
        let set = router.replicas_on_distinct_nodes(key, 2);
        assert_eq!(set.len(), 2);
        assert_ne!(set[0].1, set[1].1, "replica slots share a physical node: {set:?}");
        // …and the data is physically there, exactly twice across the
        // whole fleet.
        for (_b, n) in &set {
            assert!(
                svc.storage.node(*n).get(key).is_some(),
                "wkey{i} missing at its replica node {n}"
            );
        }
        let copies: usize =
            ids.iter().filter(|id| svc.storage.node(**id).get(key).is_some()).count();
        assert_eq!(copies, 2, "wkey{i} must exist on exactly 2 nodes");
    }
}

#[test]
fn killing_any_single_node_loses_no_acked_writes() {
    for victim in 0..WEIGHTS.len() {
        let (_router, svc, ids) = weighted_service();
        let mut acked = Vec::new();
        for i in 0..600 {
            let key = format!("k{victim}x{i}");
            let r = svc.handle(&format!("PUT {key} v{i}"));
            if r.starts_with("OK") {
                acked.push((key, format!("v{i}")));
            }
        }
        assert_eq!(acked.len(), 600, "every PUT must ack");

        let victim_name = ids[victim].to_string();
        let resp = svc.handle(&format!("KILLN {victim_name}"));
        assert!(resp.starts_with(&format!("KILLED {victim_name}")), "{resp}");
        assert!(
            resp.contains(&format!("BUCKETS {}", WEIGHTS[victim])),
            "all of the node's buckets fail together: {resp}"
        );

        // Every acknowledged write is readable immediately (replica
        // failover + in-flight-migration reads), and never from the
        // dead node.
        for (key, val) in &acked {
            let r = svc.handle(&format!("GET {key}"));
            assert!(r.contains(val), "acked write {key} lost right after KILLN: {r}");
            assert!(
                !r.starts_with(&format!("VALUE {victim_name} ")),
                "dead node {victim_name} served a read: {r}"
            );
        }
        assert!(
            svc.migration.wait_idle(Duration::from_secs(10)),
            "drain after KILLN {victim_name} timed out"
        );
        for (key, val) in &acked {
            let r = svc.handle(&format!("GET {key}"));
            assert!(r.contains(val), "acked write {key} lost after drain: {r}");
        }
        assert!(svc.storage.node(ids[victim]).is_empty(), "dead node must drain");
        let stats = svc.handle("STATS");
        assert!(stats.contains("violations=0"), "{stats}");
    }
}

#[test]
fn addw_capacity_absorbs_a_weight_proportional_share() {
    let router = Router::new("memento", 4, 200, None).unwrap();
    let svc = Service::new(router);
    let resp = svc.handle("ADDW 4");
    assert!(resp.starts_with("ADDED NODE node-4 WEIGHT 4"), "{resp}");
    assert!(svc.migration.wait_idle(Duration::from_secs(10)));
    for i in 0..2_000 {
        svc.handle(&format!("PUT ak{i} av{i}"));
    }
    // node-4 owns 4 of 8 buckets → about half the keys.
    let nodes = svc.handle("NODES");
    let held: u64 = nodes["NODES ".len()..]
        .split_whitespace()
        .find(|row| row.starts_with("node-4:"))
        .and_then(|row| row.split(':').nth(3)?.parse().ok())
        .expect("node-4 row in NODES");
    assert!(
        (700..=1_300).contains(&held),
        "weight-4/8 node holds {held} of 2000 records: {nodes}"
    );
    // Distinct-bucket draw vs distinct-node draw diverge on this
    // cluster: bucket-distinct pairs can double up on node-4.
    let mut bucket_pairs_same_node = 0;
    svc.router.with_view(|a, m| {
        for k in 0..500u64 {
            let key = memento::hashing::mix::splitmix64_mix(k);
            let pair = a.lookup_replicas_distinct(key, 2);
            let nodes: HashSet<NodeId> =
                pair.iter().map(|b| m.node_at(*b).unwrap()).collect();
            if nodes.len() < 2 {
                bucket_pairs_same_node += 1;
            }
        }
    });
    assert!(
        bucket_pairs_same_node > 0,
        "under 4:1 skew some bucket-distinct pairs must share a node — \
         the node-distinct path is load-bearing"
    );
}
