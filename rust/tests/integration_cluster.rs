//! Integration: the multi-process cluster (DESIGN.md §15). Each test
//! spawns real `memento node` child processes through the
//! `ClusterManager`, drives the fault matrix against them, and — in the
//! drill test — runs the whole detector-driven recovery loop end to
//! end with live write load and the zero-acked-write-loss check.

use memento::cluster::{run_drill, ClusterDrillConfig, ClusterManager};
use memento::testkit::faults::FaultKind;
use std::path::PathBuf;
use std::time::Duration;

const CHILD: &str = env!("CARGO_BIN_EXE_memento");

/// Generous probe deadline for CI machines; the drill's production
/// default (100 ms) is exercised by `cluster-smoke`.
const PROBE: Duration = Duration::from_millis(300);

/// Probe with a few retries — a freshly spawned or respawned child may
/// need a beat before its accept loop answers.
fn probe_soon(m: &ClusterManager, node: usize) -> bool {
    for _ in 0..20 {
        if m.probe(node, PROBE) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    false
}

#[test]
fn manager_spawns_probes_and_survives_the_fault_matrix() {
    let mut m = ClusterManager::new(PathBuf::from(CHILD));
    let a = m.spawn_node().expect("spawn node 0");
    let b = m.spawn_node().expect("spawn node 1");
    assert_eq!((a, b), (0, 1));
    assert_eq!(m.len(), 2);
    assert!(probe_soon(&m, 0), "fresh node 0 must PONG");
    assert!(probe_soon(&m, 1), "fresh node 1 must PONG");
    assert_ne!(m.addr(0), m.real_addr(0), "clients dial the proxy, not the node");

    // Crash: the process is gone; probes fail fast; restart revives the
    // slot with a new pid and port.
    let old_pid = m.pid(0);
    m.crash(0).expect("SIGKILL node 0");
    assert!(!m.is_running(0));
    assert!(!m.probe(0, PROBE), "crashed node must not answer");
    m.restart(0).expect("respawn node 0");
    assert!(m.is_running(0));
    assert_ne!(m.pid(0), old_pid, "restart is a new process");
    assert!(probe_soon(&m, 0), "restarted node must PONG");

    // Gray failure: SIGSTOP leaves sockets open but nothing answers —
    // the probe's read deadline must classify it as failure, and
    // SIGCONT must bring it straight back.
    m.stall(1).expect("SIGSTOP node 1");
    std::thread::sleep(Duration::from_millis(50));
    assert!(!m.probe(1, Duration::from_millis(150)), "stalled node must time out");
    m.resume(1).expect("SIGCONT node 1");
    assert!(probe_soon(&m, 1), "thawed node must PONG");

    // Partition: the node process is perfectly healthy but its bytes
    // vanish at the proxy; healing restores fresh connections.
    m.partition(1);
    assert!(!m.probe(1, Duration::from_millis(150)), "partitioned node must time out");
    m.heal(1);
    assert!(probe_soon(&m, 1), "healed node must PONG");

    m.shutdown();
    assert!(!m.probe(0, PROBE));
    assert!(!m.probe(1, PROBE));
}

/// The mini acceptance drill: one SIGKILL crash against a 3-node
/// cluster under live write load. The detector must confirm the death
/// (driving the real `KILLN` + migration drain), the respawned node
/// must rejoin via `ADD` + snapshot install, and every acked write must
/// read back afterwards. The larger CI shape (4 nodes, crash +
/// partition) runs in the `cluster-smoke` job via the binary.
#[test]
fn crash_drill_detects_drains_and_rejoins_losslessly() {
    let mut cfg = ClusterDrillConfig::new(PathBuf::from(CHILD));
    cfg.nodes = 3;
    cfg.writers = 1;
    cfg.duration = Duration::from_millis(1500);
    cfg.faults = vec![FaultKind::Crash];
    let rep = run_drill(&cfg).expect("drill must run");
    assert!(
        rep.pass(),
        "cluster drill failed:\n  {}\n  errors: {:?}\n  lost: {:?}",
        rep.summary(),
        rep.errors,
        rep.lost
    );
    assert_eq!(rep.detections, 1, "exactly one detector-driven KILLN");
    assert_eq!(rep.rejoins, 1, "the crashed node must rejoin");
    assert!(rep.faults[0].detect_ms.is_some(), "detection latency measured");
    assert!(rep.acked_writes > 0, "the writers made progress");
    assert!(!rep.availability.is_empty(), "per-second availability collected");
    // The JSON payload carries the gated figures.
    let j = rep.to_json();
    assert!(j.contains("\"bench\": \"cluster_drill\""), "{j}");
    assert!(j.contains("\"lost_writes\": 0"), "{j}");
}

/// A partition (bytes vanish, process healthy) must be detected and
/// recovered exactly like a crash — the gray path the read deadline
/// exists for.
#[test]
fn partition_drill_recovers_through_the_proxy() {
    let mut cfg = ClusterDrillConfig::new(PathBuf::from(CHILD));
    cfg.nodes = 3;
    cfg.writers = 1;
    cfg.duration = Duration::from_millis(1500);
    cfg.faults = vec![FaultKind::Partition];
    let rep = run_drill(&cfg).expect("drill must run");
    assert!(
        rep.pass(),
        "partition drill failed:\n  {}\n  errors: {:?}\n  lost: {:?}",
        rep.summary(),
        rep.errors,
        rep.lost
    );
    assert_eq!(rep.faults[0].kind, "partition");
    assert_eq!(rep.rejoins, 1);
}
