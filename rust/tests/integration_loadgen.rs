//! Integration: the loadgen subsystem end to end — many pipelined TCP
//! clients under mid-load failures with replication, open-loop
//! coordinated-omission correction, and full closed-loop runs.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::loadgen::{self, ChurnScenario, LoadgenConfig, Mode, Target, Workload};
use memento::netserver::{Client, ClientError};
use memento::proto::Request;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so assertions stay line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

/// ≥8 pipelined TCP clients issue PUT/GET while a KILL fires mid-load;
/// with replication no acknowledged write may be lost.
#[test]
fn pipelined_clients_survive_kill_without_losing_acked_writes() {
    let router = Router::new("memento", 10, 100, None).unwrap();
    let svc = Service::with_replicas(router, 2);
    let server = svc.serve("127.0.0.1:0", 64).unwrap();
    let addr = server.addr();

    let start_line = Arc::new(Barrier::new(9)); // 8 writers + the killer
    let writers: Vec<_> = (0..8)
        .map(|t| {
            let start_line = start_line.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                start_line.wait();
                let mut acked: Vec<String> = Vec::new();
                for i in 0..300 {
                    let key = format!("c{t}k{i}");
                    let r = req(&mut c, &format!("PUT {key} val{t}x{i}"));
                    if r.starts_with("OK") {
                        acked.push(key);
                    }
                    // Pipelined read-back on the same connection keeps a
                    // GET/PUT mix in flight during the failure.
                    if i % 3 == 0 {
                        if let Some(k) = acked.last() {
                            let r = req(&mut c, &format!("GET {k}"));
                            assert!(r.starts_with("VALUE"), "read-your-write {k}: {r}");
                        }
                    }
                }
                acked
            })
        })
        .collect();
    let killer = {
        let start_line = start_line.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            start_line.wait();
            std::thread::sleep(Duration::from_millis(10));
            let r = req(&mut c, "KILL 4");
            assert!(r.starts_with("KILLED"), "{r}");
        })
    };
    let acked: Vec<String> =
        writers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    killer.join().unwrap();
    assert_eq!(acked.len(), 8 * 300, "every PUT must be acknowledged");

    // Every acknowledged write must be readable after the failure.
    let mut c = Client::connect(&addr).unwrap();
    for key in &acked {
        let r = req(&mut c, &format!("GET {key}"));
        assert!(r.starts_with("VALUE"), "acknowledged write {key} lost: {r}");
    }
    let stats = req(&mut c, "STATS");
    assert!(stats.contains("violations=0"), "{stats}");
    drop(c);
    assert_eq!(server.shutdown(), 0, "connections must drain on shutdown");
}

/// A target that stalls once, mid-run: the service equivalent of a GC
/// pause or failover blip. The open-loop pacer must charge the backlog
/// the full queueing delay.
struct StallingTarget {
    svc: Arc<Service>,
    calls: u64,
    stall_at: u64,
    stall: Duration,
}

impl Target for StallingTarget {
    fn call(&mut self, line: &str) -> std::io::Result<String> {
        self.calls += 1;
        if self.calls == self.stall_at {
            std::thread::sleep(self.stall);
        }
        Ok(self.svc.handle(line))
    }
}

#[test]
fn open_loop_pacer_corrects_coordinated_omission() {
    let router = Router::new("memento", 8, 80, None).unwrap();
    let svc = Service::new(router);
    let svc2 = svc.clone();
    let factory: loadgen::TargetFactory = Arc::new(move || {
        Ok(Box::new(StallingTarget {
            svc: svc2.clone(),
            calls: 0,
            stall_at: 200,
            stall: Duration::from_millis(300),
        }) as Box<dyn Target>)
    });
    let cfg = LoadgenConfig {
        mode: Mode::Open { rate: 2_000.0 },
        workload: Workload::uniform(10_000, 0.5),
        threads: 1,
        duration: Duration::from_secs(1),
        churn: ChurnScenario::Stable,
        cluster_buckets: 8,
        seed: 1,
    };
    let rep = loadgen::run(&cfg, &factory).unwrap();
    assert!(rep.ops > 1_000, "ops {}", rep.ops);

    let corrected_p99 = rep.corrected.quantile(0.99);
    let naive_p99 = rep.naive.quantile(0.99);
    // The invariant: measuring from intended arrival can only add queueing
    // delay on top of service time.
    assert!(
        corrected_p99 >= naive_p99,
        "corrected p99 {corrected_p99} < naive p99 {naive_p99}"
    );
    // The 300 ms stall at ~10% of a 2000-arrival schedule backlogs ~600
    // paced arrivals (~30% of the run), so the corrected p99 must see a
    // triple-digit-ms latency; the naive send-to-response measurement
    // observes a single slow call (~0.05% of ops) and hides the rest.
    assert!(
        corrected_p99 > 50_000_000,
        "corrected p99 {corrected_p99} ns misses the stall backlog"
    );
    assert!(
        naive_p99 < corrected_p99 / 2,
        "naive p99 {naive_p99} should hide most of the stall (corrected {corrected_p99})"
    );
}

#[test]
fn closed_loop_inproc_run_reports_sane_percentiles() {
    let router = Router::new("memento", 8, 80, None).unwrap();
    let svc = Service::new(router);
    let factory = loadgen::target::inproc_factory(svc.clone());
    assert_eq!(loadgen::preload(&factory, 1_000).unwrap(), 1_000);
    let cfg = LoadgenConfig {
        mode: Mode::Closed,
        workload: Workload::zipf(1_000, 1.1, 0.8),
        threads: 4,
        duration: Duration::from_millis(300),
        churn: ChurnScenario::Stable,
        cluster_buckets: 8,
        seed: 42,
    };
    let rep = loadgen::run(&cfg, &factory).unwrap();
    assert!(rep.ops > 1_000, "ops {}", rep.ops);
    assert_eq!(rep.errors, 0);
    let p50 = rep.corrected.quantile(0.5);
    let p99 = rep.corrected.quantile(0.99);
    let p999 = rep.corrected.quantile(0.999);
    assert!(p50 <= p99 && p99 <= p999, "p50={p50} p99={p99} p999={p999}");
    assert!(rep.throughput() > 1_000.0, "throughput {}", rep.throughput());
    let json = rep.to_json();
    assert!(json.contains("\"p99\""), "{json}");
    // The service-side histogram saw the same traffic.
    let stats = svc.handle("STATS");
    assert!(stats.contains("latency(ns):"), "{stats}");
}

#[test]
fn open_loop_with_incremental_churn_over_tcp() {
    let router = Router::new("memento", 12, 120, None).unwrap();
    let svc = Service::with_replicas(router.clone(), 2);
    let server = svc.serve("127.0.0.1:0", 64).unwrap();
    let factory = loadgen::target::tcp_factory(server.addr());
    assert_eq!(loadgen::preload(&factory, 500).unwrap(), 500);
    let cfg = LoadgenConfig {
        mode: Mode::Open { rate: 4_000.0 },
        workload: Workload::hot(500, 0.9, 16, 0.7),
        threads: 4,
        duration: Duration::from_millis(800),
        churn: ChurnScenario::Incremental { kills: 3 },
        cluster_buckets: 12,
        seed: 9,
    };
    let rep = loadgen::run(&cfg, &factory).unwrap();
    assert!(rep.ops > 500, "ops {}", rep.ops);
    // 3 kills + 3 restores bump the epoch six times.
    assert_eq!(router.epoch(), 6, "churn must fire through the protocol");
    assert_eq!(router.working(), 12, "restores must bring capacity back");
    assert_eq!(rep.churn_events.len(), 6, "{:?}", rep.churn_events);
    // Placement audit stays clean across the whole schedule.
    let stats = svc.handle("STATS");
    assert!(stats.contains("violations=0"), "{stats}");
    assert_eq!(server.shutdown(), 0, "connections must drain on shutdown");
}
