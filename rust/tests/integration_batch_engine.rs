//! The pure-Rust batch engine vs the scalar algorithm under membership
//! churn.
//!
//! Acceptance property of the dependency-free runtime: for every key,
//! batched lookups agree with the scalar `Memento` lookup at *every*
//! epoch of an arbitrary add/remove schedule — including deep removals,
//! LIFO restores, tail growth and interleavings of all three.

use memento::algorithms::{ConsistentHasher, Memento};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::runtime::{BatchEngine, Engine, EngineSnapshot, EngineStats, LookupBackend};

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

/// Assert the backend agrees with the scalar algorithm on every key.
fn assert_batch_matches_scalar(
    m: &Memento,
    ks: &[u64],
    be: &BatchEngine,
    stats: &EngineStats,
    label: &str,
) {
    let snap = EngineSnapshot::new(m.clone(), m.size());
    let got = be.memento_lookup_snapshot(&snap, ks, stats).expect("batched lookup");
    assert_eq!(got.len(), ks.len());
    for (k, g) in ks.iter().zip(&got) {
        assert_eq!(*g, m.lookup(*k), "{label}: key {k:#x} diverged");
    }
}

#[test]
fn batched_lookups_agree_with_scalar_across_random_churn() {
    let be = BatchEngine::new();
    let stats = EngineStats::default();
    let mut rng = Xoshiro256::new(0xC4C4);
    let mut m = Memento::new(200);
    let ks = keys(4096, 0xFEED);

    assert_batch_matches_scalar(&m, &ks, &be, &stats, "epoch 0");
    for epoch in 1..=60 {
        // Biased random schedule: ~1/3 adds (LIFO restores or tail
        // growth), ~2/3 random removals.
        if rng.next_below(3) == 0 {
            m.add().expect("add");
        } else if m.working() > 1 {
            let wb = m.working_buckets();
            let b = wb[rng.next_index(wb.len())];
            m.remove(b).expect("remove working bucket");
        }
        assert_batch_matches_scalar(&m, &ks, &be, &stats, &format!("epoch {epoch}"));
    }
    assert!(stats.fallback_rate() < 1e-3, "rate {}", stats.fallback_rate());
    assert!(stats.device_keys.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

#[test]
fn deep_removal_then_full_restore_cycle() {
    let be = BatchEngine::new();
    let stats = EngineStats::default();
    let mut rng = Xoshiro256::new(0xDEE9);
    let mut m = Memento::new(500);
    let ks = keys(2048, 0xD00D);

    // Remove 90% of the nodes one by one, checking at every 50th epoch.
    let mut removed = 0;
    while m.working() > 50 {
        let wb = m.working_buckets();
        let b = wb[rng.next_index(wb.len())];
        m.remove(b).unwrap();
        removed += 1;
        if removed % 50 == 0 {
            assert_batch_matches_scalar(&m, &ks, &be, &stats, &format!("down {removed}"));
        }
    }
    assert_batch_matches_scalar(&m, &ks, &be, &stats, "90% removed");

    // Restore everything (Alg. 3 LIFO), checking along the way.
    let mut restored = 0;
    while m.removed() > 0 {
        m.add().unwrap();
        restored += 1;
        if restored % 50 == 0 {
            assert_batch_matches_scalar(&m, &ks, &be, &stats, &format!("up {restored}"));
        }
    }
    assert_eq!(m.working(), m.size());
    assert_batch_matches_scalar(&m, &ks, &be, &stats, "fully restored");

    // Grow past the original size (tail growth) and verify again.
    for _ in 0..25 {
        m.add().unwrap();
    }
    assert_batch_matches_scalar(&m, &ks, &be, &stats, "grown past initial");
}

#[test]
fn tiny_clusters_and_tiny_batches() {
    let be = BatchEngine::new();
    let stats = EngineStats::default();
    // w = 1..=4 with every removal pattern reachable by a short schedule.
    for w in 1usize..=4 {
        let mut m = Memento::new(w);
        let ks = keys(33, w as u64);
        assert_batch_matches_scalar(&m, &ks, &be, &stats, &format!("w={w} stable"));
        if w > 1 {
            m.remove(0).unwrap();
            assert_batch_matches_scalar(&m, &ks, &be, &stats, &format!("w={w} head removed"));
        }
    }
    // Single-key batches.
    let mut m = Memento::new(10);
    m.remove(4).unwrap();
    for k in keys(16, 1) {
        assert_batch_matches_scalar(&m, &[k], &be, &stats, "single key");
    }
}

#[test]
fn frontend_engine_matches_scalar_through_churn() {
    // Same churn property through the public `Engine` frontend (what the
    // router and benches use), exercising snapshot construction per epoch.
    let engine = Engine::new();
    let mut rng = Xoshiro256::new(0x0FF);
    let mut m = Memento::new(128);
    let ks = keys(4096, 0xAB);
    for epoch in 0..30 {
        if rng.next_below(4) == 0 {
            m.add().unwrap();
        } else if m.working() > 1 {
            let wb = m.working_buckets();
            m.remove(wb[rng.next_index(wb.len())]).unwrap();
        }
        let got = engine.memento_lookup(&m, &ks).unwrap();
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, m.lookup(*k), "epoch {epoch} key {k:#x}");
        }
    }
}
