//! Integration: the full service over real TCP — protocol, concurrent
//! clients, failure + restore with live data migration.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::netserver::{Client, ClientError};
use memento::proto::Request;

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so assertions stay line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

fn start() -> (std::sync::Arc<Service>, memento::netserver::ServerHandle) {
    let router = Router::new("memento", 8, 80, None).unwrap();
    let svc = Service::new(router);
    let handle = svc.serve("127.0.0.1:0", 64).unwrap();
    (svc, handle)
}

#[test]
fn tcp_protocol_roundtrip() {
    let (_svc, server) = start();
    let mut c = Client::connect(&server.addr()).unwrap();
    let r = req(&mut c, "PUT user:42 alice");
    assert!(r.starts_with("OK node-"), "{r}");
    let r = req(&mut c, "GET user:42");
    assert!(r.contains("alice"), "{r}");
    let r = req(&mut c, "LOOKUP user:42");
    assert!(r.starts_with("BUCKET "), "{r}");
    let r = req(&mut c, "EPOCH");
    assert_eq!(r, "EPOCH 0 WORKING 8");
    // QUIT is transport-level: `Client::close` sends it and waits for
    // the server's BYE ack (the DESIGN.md §13 shim-removal endgame).
    c.close().unwrap();
    server.shutdown();
}

#[test]
fn failure_drill_over_tcp() {
    let (_svc, server) = start();
    let mut c = Client::connect(&server.addr()).unwrap();
    for i in 0..200 {
        req(&mut c, &format!("PUT key{i} value{i}"));
    }
    let r = req(&mut c, "KILL 5");
    assert!(r.starts_with("KILLED node-"), "{r}");
    // All data still reachable.
    for i in 0..200 {
        let r = req(&mut c, &format!("GET key{i}"));
        assert!(r.contains(&format!("value{i}")), "key{i}: {r}");
    }
    // Restore brings the node back on the same bucket.
    let r = req(&mut c, "ADD");
    assert!(r.contains("BUCKET 5"), "{r}");
    for i in 0..200 {
        let r = req(&mut c, &format!("GET key{i}"));
        assert!(r.contains(&format!("value{i}")), "after restore key{i}: {r}");
    }
    let stats = req(&mut c, "STATS");
    assert!(stats.contains("violations=0"), "{stats}");
    server.shutdown();
}

#[test]
fn concurrent_clients_and_failures() {
    let (_svc, server) = start();
    let addr = server.addr();
    // Writers fill the store while a chaos thread kills/restores nodes.
    let writers: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for i in 0..150 {
                    let r = req(&mut c, &format!("PUT w{t}k{i} v{t}x{i}"));
                    assert!(r.starts_with("OK"), "{r}");
                }
            })
        })
        .collect();
    let chaos = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        for round in 0..4 {
            std::thread::sleep(std::time::Duration::from_millis(3));
            let bucket = 1 + round;
            let _ = req(&mut c, &format!("KILL {bucket}"));
            std::thread::sleep(std::time::Duration::from_millis(3));
            let _ = req(&mut c, "ADD");
        }
    });
    for w in writers {
        w.join().unwrap();
    }
    chaos.join().unwrap();
    // Every write must be readable afterwards.
    let mut c = Client::connect(&addr).unwrap();
    for t in 0..4 {
        for i in 0..150 {
            let r = req(&mut c, &format!("GET w{t}k{i}"));
            assert!(r.contains(&format!("v{t}x{i}")), "w{t}k{i}: {r}");
        }
    }
    let stats = req(&mut c, "STATS");
    assert!(stats.contains("violations=0"), "{stats}");
    server.shutdown();
}

#[test]
fn config_file_drives_service() {
    let toml = r#"
[router]
algorithm = "anchor"
initial_nodes = 6
capacity_factor = 10
"#;
    let cfg = memento::config::RouterConfig::from_toml(toml).unwrap();
    let router = Router::new(
        &cfg.algorithm,
        cfg.initial_nodes,
        cfg.initial_nodes * cfg.capacity_factor,
        None,
    )
    .unwrap();
    let svc = Service::new(router);
    assert_eq!(svc.handle("EPOCH"), "EPOCH 0 WORKING 6");
    let r = svc.handle("PUT x 1");
    assert!(r.starts_with("OK"));
}
