//! Integration: the PJRT engine vs the scalar rust implementations.
//!
//! THE cross-language correctness signal: the AOT-compiled JAX/Pallas
//! kernels must agree bit-for-bit with `algorithms::{jump_hash, Memento}`
//! for every key. Requires `make artifacts` (tests are skipped with a
//! notice if the artifacts are absent, so `cargo test` works standalone).

use memento::algorithms::{jump_hash, ConsistentHasher, Memento, RemovalOrder};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::runtime::{ArtifactCatalog, Engine};
use memento::simulator::scenario;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if ArtifactCatalog::scan(dir).is_empty() {
        eprintln!("[skip] no artifacts/ — run `make artifacts` for engine tests");
        None
    } else {
        Some(dir)
    }
}

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

#[test]
fn engine_jump_matches_scalar() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    assert!(engine.has_jump());
    for n in [1u32, 2, 10, 1000, 1_000_000, 100_000_000] {
        let ks = keys(4096, n as u64);
        let got = engine.jump_lookup(&ks, n).expect("device lookup");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, jump_hash(*k, n), "key {k:#x} n {n}");
        }
    }
    // Convergence bound is generous: fallback rate ≈ 0.
    assert!(engine.stats.fallback_rate() < 0.001, "rate {}", engine.stats.fallback_rate());
}

#[test]
fn engine_jump_handles_tails_and_large_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    // 10_000 keys: 2 full chunks of 4096 + a 1808-key tail (device),
    // plus odd sizes below the dispatch threshold (scalar).
    for len in [1usize, 37, 1023, 10_000] {
        let ks = keys(len, 9);
        let got = engine.jump_lookup(&ks, 12345).unwrap();
        assert_eq!(got.len(), len);
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, jump_hash(*k, 12345));
        }
    }
}

#[test]
fn engine_memento_matches_scalar_across_removal_patterns() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    assert!(engine.has_memento());
    let mut rng = Xoshiro256::new(0xE2E);
    for (w, removals) in [(100usize, 30usize), (1000, 650), (4096, 1000), (10_000, 2_000)] {
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let ks = keys(8192, w as u64);
        let got = engine.memento_lookup(&m, &ks).expect("device memento");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, m.lookup(*k), "w={w} removals={removals} key {k:#x}");
        }
    }
}

#[test]
fn engine_memento_stable_cluster_equals_jump() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    let m = Memento::new(1000);
    let ks = keys(4096, 5);
    let got = engine.memento_lookup(&m, &ks).unwrap();
    for (k, g) in ks.iter().zip(&got) {
        assert_eq!(*g, jump_hash(*k, 1000));
    }
}

#[test]
fn engine_memento_lifo_equals_plain_jump_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    let mut m = Memento::new(500);
    for b in (300..500u32).rev() {
        m.remove(b).unwrap();
    }
    assert_eq!(m.removed(), 0, "LIFO keeps R empty");
    let ks = keys(4096, 6);
    let via_memento = engine.memento_lookup(&m, &ks).unwrap();
    let via_jump = engine.jump_lookup(&ks, 300).unwrap();
    assert_eq!(via_memento, via_jump);
}

#[test]
fn engine_histogram_matches_host_bincount() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    if !engine.has_hist() {
        return;
    }
    let m = Memento::new(64);
    let ks = keys(8192, 11);
    let buckets: Vec<u32> = ks.iter().map(|&k| m.lookup(k)).collect();
    let dev = engine.histogram(&buckets, 64).unwrap();
    let mut host = vec![0u64; 64];
    for &b in &buckets {
        host[b as usize] += 1;
    }
    assert_eq!(dev, host);
    assert_eq!(dev.iter().sum::<u64>(), 8192);
}

#[test]
fn engine_handle_works_across_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let handle =
        memento::runtime::EngineHandle::spawn(dir.to_path_buf()).expect("spawn engine thread");
    assert!(handle.info().has_memento);
    let mut m = Memento::new(256);
    for b in [3u32, 99, 200, 17] {
        m.remove(b).unwrap();
    }
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let h = handle.clone();
            let m = m.clone();
            std::thread::spawn(move || {
                let ks = keys(4096, t);
                let got = h.memento_lookup(m.clone(), ks.clone()).unwrap();
                for (k, g) in ks.iter().zip(&got) {
                    assert_eq!(*g, m.lookup(*k));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let (device, fallback, dispatches) = handle.stats();
    assert!(device > 0);
    assert!(dispatches >= 4);
    assert!((fallback as f64) / ((device + fallback) as f64) < 0.01);
}

#[test]
fn engine_property_random_clusters_match_scalar() {
    // Property-style sweep: random (w, removal-fraction) clusters.
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(dir).expect("engine loads");
    let mut rng = Xoshiro256::new(0x5EED);
    for case in 0..12 {
        let w = 2 + rng.next_below(5000) as usize;
        let frac = rng.next_f64() * 0.9;
        let removals = ((w as f64) * frac) as usize;
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let ks = keys(4096, case);
        let got = engine.memento_lookup(&m, &ks).expect("device");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, m.lookup(*k), "case {case} w={w} frac={frac:.2}");
        }
    }
}
