//! Integration: the batched-lookup engine vs the scalar rust
//! implementations.
//!
//! THE correctness signal for the runtime layer: batched lookups must
//! agree bit-for-bit with `algorithms::{jump_hash, Memento}` for every
//! key. `Engine::load` always yields a working backend — the pure-Rust
//! batch engine by default — so these tests run everywhere with no
//! artifacts; with `--features pjrt` and a real PJRT runtime wired in,
//! the same assertions exercise the device path.

use memento::algorithms::{jump_hash, ConsistentHasher, Memento, RemovalOrder};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::runtime::Engine;
use memento::simulator::scenario;
use std::path::Path;

fn engine() -> Engine {
    Engine::load(Path::new("artifacts")).expect("engine backend")
}

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

#[test]
fn engine_jump_matches_scalar() {
    let engine = engine();
    assert!(engine.has_jump());
    for n in [1u32, 2, 10, 1000, 1_000_000, 100_000_000] {
        let ks = keys(4096, n as u64);
        let got = engine.jump_lookup(&ks, n).expect("batched lookup");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, jump_hash(*k, n), "key {k:#x} n {n}");
        }
    }
    // Convergence bound is generous: fallback rate ≈ 0.
    assert!(engine.stats.fallback_rate() < 0.001, "rate {}", engine.stats.fallback_rate());
}

#[test]
fn engine_jump_handles_tails_and_odd_sizes() {
    let engine = engine();
    for len in [1usize, 37, 1023, 10_000] {
        let ks = keys(len, 9);
        let got = engine.jump_lookup(&ks, 12345).unwrap();
        assert_eq!(got.len(), len);
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, jump_hash(*k, 12345));
        }
    }
}

#[test]
fn engine_memento_matches_scalar_across_removal_patterns() {
    let engine = engine();
    assert!(engine.has_memento());
    let mut rng = Xoshiro256::new(0xE2E);
    for (w, removals) in [(100usize, 30usize), (1000, 650), (4096, 1000), (10_000, 2_000)] {
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let ks = keys(8192, w as u64);
        let got = engine.memento_lookup(&m, &ks).expect("batched memento");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, m.lookup(*k), "w={w} removals={removals} key {k:#x}");
        }
    }
}

#[test]
fn engine_memento_stable_cluster_equals_jump() {
    let engine = engine();
    let m = Memento::new(1000);
    let ks = keys(4096, 5);
    let got = engine.memento_lookup(&m, &ks).unwrap();
    for (k, g) in ks.iter().zip(&got) {
        assert_eq!(*g, jump_hash(*k, 1000));
    }
}

#[test]
fn engine_memento_lifo_equals_batched_jump() {
    let engine = engine();
    let mut m = Memento::new(500);
    for b in (300..500u32).rev() {
        m.remove(b).unwrap();
    }
    assert_eq!(m.removed(), 0, "LIFO keeps R empty");
    let ks = keys(4096, 6);
    let via_memento = engine.memento_lookup(&m, &ks).unwrap();
    let via_jump = engine.jump_lookup(&ks, 300).unwrap();
    assert_eq!(via_memento, via_jump);
}

#[test]
fn engine_histogram_matches_host_bincount() {
    let engine = engine();
    assert!(engine.has_hist());
    let m = Memento::new(64);
    let ks = keys(8192, 11);
    let buckets: Vec<u32> = ks.iter().map(|&k| m.lookup(k)).collect();
    let dev = engine.histogram(&buckets, 64).unwrap();
    let mut host = vec![0u64; 64];
    for &b in &buckets {
        host[b as usize] += 1;
    }
    assert_eq!(dev, host);
    assert_eq!(dev.iter().sum::<u64>(), 8192);
}

#[test]
fn engine_handle_works_across_threads() {
    let handle =
        memento::runtime::EngineHandle::spawn("artifacts".into()).expect("spawn engine thread");
    assert!(handle.info().has_memento);
    assert!(!handle.info().platform.is_empty());
    let mut m = Memento::new(256);
    for b in [3u32, 99, 200, 17] {
        m.remove(b).unwrap();
    }
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let h = handle.clone();
            let m = m.clone();
            std::thread::spawn(move || {
                let ks = keys(4096, t);
                let got = h.memento_lookup(m.clone(), ks.clone()).unwrap();
                for (k, g) in ks.iter().zip(&got) {
                    assert_eq!(*g, m.lookup(*k));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let (device, fallback, dispatches) = handle.stats();
    assert!(device > 0);
    assert!(dispatches >= 4);
    assert!((fallback as f64) / ((device + fallback) as f64) < 0.01);
}

#[test]
fn engine_snapshot_path_matches_oneshot_path() {
    let handle =
        memento::runtime::EngineHandle::spawn("artifacts".into()).expect("spawn engine thread");
    let mut m = Memento::new(1024);
    for b in [5u32, 700, 701, 3, 999] {
        m.remove(b).unwrap();
    }
    let snap = handle.snapshot(m.clone()).expect("snapshot");
    let ks = keys(8192, 77);
    let via_snap = handle.memento_lookup_snapshot(snap.clone(), ks.clone()).unwrap();
    let via_oneshot = handle.memento_lookup(m.clone(), ks.clone()).unwrap();
    assert_eq!(via_snap, via_oneshot);
    // Re-dispatching the same snapshot must stay consistent (upload/cache
    // reuse on backends that cache table uploads).
    let again = handle.memento_lookup_snapshot(snap, ks.clone()).unwrap();
    assert_eq!(again, via_snap);
    for (k, g) in ks.iter().zip(&via_snap) {
        assert_eq!(*g, m.lookup(*k));
    }
}

#[test]
fn engine_property_random_clusters_match_scalar() {
    // Property-style sweep: random (w, removal-fraction) clusters.
    let engine = engine();
    let mut rng = Xoshiro256::new(0x5EED);
    for case in 0..12 {
        let w = 2 + rng.next_below(5000) as usize;
        let frac = rng.next_f64() * 0.9;
        let removals = ((w as f64) * frac) as usize;
        let mut m = Memento::new(w);
        scenario::apply_removals(&mut m, removals, RemovalOrder::Random, &mut rng);
        let ks = keys(4096, case);
        let got = engine.memento_lookup(&m, &ks).expect("batched");
        for (k, g) in ks.iter().zip(&got) {
            assert_eq!(*g, m.lookup(*k), "case {case} w={w} frac={frac:.2}");
        }
    }
}

#[test]
fn custom_hasher_snapshots_stay_exact() {
    // Non-default rehash functions have no batched kernel: the engine
    // must serve them on the exact scalar path instead of diverging.
    let engine = engine();
    let h: std::sync::Arc<dyn memento::hashing::Hasher64> =
        memento::hashing::by_name("xxhash64").expect("registry hasher").into();
    let mut m = Memento::with_hasher(512, h);
    for b in [100u32, 200, 300, 301, 302] {
        m.remove(b).unwrap();
    }
    let ks = keys(4096, 21);
    let before_fallback = engine.stats.fallback_keys.load(std::sync::atomic::Ordering::Relaxed);
    let got = engine.memento_lookup(&m, &ks).unwrap();
    for (k, g) in ks.iter().zip(&got) {
        assert_eq!(*g, m.lookup(*k), "key {k:#x}");
    }
    let after_fallback = engine.stats.fallback_keys.load(std::sync::atomic::Ordering::Relaxed);
    assert!(after_fallback >= before_fallback + ks.len() as u64, "scalar path must serve all keys");
}

#[test]
fn router_route_batch_matches_scalar_route() {
    use memento::coordinator::router::Router;
    let handle =
        memento::runtime::EngineHandle::spawn("artifacts".into()).expect("spawn engine thread");
    let router = Router::new("memento", 64, 640, Some(handle)).unwrap();
    router.fail_bucket(7).unwrap();
    router.fail_bucket(40).unwrap();
    let ks = keys(8192, 0xB0);
    let batched = router.route_batch(&ks);
    for (k, b) in ks.iter().zip(&batched) {
        assert_eq!(router.route(*k).0, *b);
    }
    assert!(router.metrics.lookups_batched.get() >= ks.len() as u64);
}
