//! Integration: the epoch-delta migration pipeline end to end — O(1)
//! admin commands, background drain, read availability during movement,
//! planner-delta soundness against observed key movement, and the
//! full-scan fallback for algorithms without structural deltas.

use memento::algorithms::ConsistentHasher;
use memento::coordinator::migration::{MigrationConfig, MigrationPlan, Migrator, PlanKind};
use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::netserver::{Client, ClientError};
use memento::proto::Request;
use memento::simulator::audit;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so assertions stay line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

fn wait_mstat_idle(c: &mut Client, timeout: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        let r = req(c, "MSTAT");
        assert!(r.starts_with("MSTAT"), "{r}");
        if r.contains("idle=true") {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    false
}

/// The satellite scenario: pipelined GET/PUT clients drive a replicated
/// service through KILL → drain → ADD → drain. No acknowledged write may
/// be lost, the admin commands must ack within a bounded window, and the
/// executor must move exactly what the planner planned.
#[test]
fn kill_drain_add_under_pipelined_traffic() {
    let router = Router::new("memento", 10, 100, None).unwrap();
    let svc = Service::with_replicas(router, 2);
    let server = svc.serve("127.0.0.1:0", 64).unwrap();
    let addr = server.addr();

    let start_line = Arc::new(Barrier::new(9)); // 8 writers + the churner
    let writers: Vec<_> = (0..8)
        .map(|t| {
            let start_line = start_line.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                start_line.wait();
                let mut acked: Vec<String> = Vec::new();
                for i in 0..600 {
                    let key = format!("m{t}k{i}");
                    let r = req(&mut c, &format!("PUT {key} val{t}x{i}"));
                    if r.starts_with("OK") {
                        acked.push(key);
                    }
                    // Keep GETs in flight through the churn: every write
                    // must be readable the moment it is acknowledged.
                    if i % 3 == 0 {
                        if let Some(k) = acked.last() {
                            let r = req(&mut c, &format!("GET {k}"));
                            assert!(r.starts_with("VALUE"), "read-your-write {k}: {r}");
                        }
                    }
                }
                acked
            })
        })
        .collect();

    let churner = {
        let start_line = start_line.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            start_line.wait();
            std::thread::sleep(Duration::from_millis(5));
            // KILL acks fast (it only publishes + enqueues)…
            let t0 = Instant::now();
            let r = req(&mut c, "KILL 4");
            let kill_rtt = t0.elapsed();
            assert!(r.starts_with("KILLED"), "{r}");
            assert!(kill_rtt < Duration::from_millis(250), "KILL ack took {kill_rtt:?}");
            // …and the availability window (drain) is bounded.
            assert!(
                wait_mstat_idle(&mut c, Duration::from_secs(10)),
                "drain after KILL timed out"
            );
            let t0 = Instant::now();
            let r = req(&mut c, "ADD");
            let add_rtt = t0.elapsed();
            assert!(r.contains("BUCKET 4"), "restore must reuse bucket 4: {r}");
            assert!(add_rtt < Duration::from_millis(250), "ADD ack took {add_rtt:?}");
            assert!(
                wait_mstat_idle(&mut c, Duration::from_secs(10)),
                "drain after ADD timed out"
            );
        })
    };

    let acked: Vec<String> = writers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    churner.join().unwrap();
    assert_eq!(acked.len(), 8 * 600, "every PUT must be acknowledged");
    assert!(svc.migration.wait_idle(Duration::from_secs(10)), "queue must drain");

    // Zero acknowledged-write loss across the whole churn cycle.
    let mut c = Client::connect(&addr).unwrap();
    for key in &acked {
        let r = req(&mut c, &format!("GET {key}"));
        assert!(r.starts_with("VALUE"), "acknowledged write {key} lost: {r}");
    }
    // The executor moved exactly the planner's key set: every planned
    // mover was extracted and relocated, nothing else was touched.
    let planned = svc.router.metrics.keys_planned.get();
    let moved = svc.router.metrics.keys_moved.get();
    assert!(moved > 0, "the drain must have moved records");
    assert_eq!(planned, moved, "executor must move exactly the planned set");
    let stats = req(&mut c, "STATS");
    assert!(stats.contains("violations=0"), "collateral movement: {stats}");
    drop(c);
    assert_eq!(server.shutdown(), 0, "connections must drain on shutdown");
}

/// Property test over random churn: the planner's delta always covers
/// the observed tracer-key movement (zero stranded keys), Memento never
/// falls back to a full scan for kills/restores, and a restore's scanned
/// set is exactly the replacement-chain source set.
#[test]
fn planner_delta_matches_observed_movement_across_random_churn() {
    let tracers: Vec<u64> = (0..20_000u64).map(memento::hashing::mix::splitmix64_mix).collect();
    let router = Router::new("memento", 24, 240, None).unwrap();
    // Deterministic “random” schedule: kills and restores interleaved.
    let kills = [7u32, 19, 3, 11, 22, 5, 15, 9];
    let mut step = 0usize;
    let mut do_step = |restore: bool| {
        let seed = if restore {
            let ((_b, _n), mut seeds) = router.add_node_planned().unwrap();
            assert_eq!(seeds.len(), 1, "weight-1 restore is one bucket step");
            seeds.pop().unwrap()
        } else {
            let (_n, seed) = router.fail_bucket_planned(kills[step % kills.len()]).unwrap();
            step += 1;
            seed
        };
        let delta = seed.delta.clone();
        assert!(!delta.full_scan, "memento kills/restores must never full-scan");
        // Soundness: observed movement ⊆ planned sources.
        let old_algo = seed.old_placement.algo();
        router.with_view(|new_algo, _m| {
            let rep = audit::delta_coverage(old_algo, new_algo, &delta, &tracers);
            assert_eq!(rep.missed, 0, "stranded movers (restore={restore}): {rep:?}");
            assert!(rep.moved > 0, "churn must move tracer keys");
        });
        // Restores scan exactly the replacement-chain sources.
        if restore {
            let old_memento = seed.old_placement.memento_snapshot().expect("memento placement");
            let chain = old_memento.restore_sources(seed.changed_buckets[0]).unwrap();
            assert_eq!(delta.sources, chain, "restore delta must equal the chain source set");
            assert!(
                chain.len() <= old_memento.working(),
                "chain sources cannot exceed the working set"
            );
        }
    };
    // kill, kill, restore, kill, restore, restore, kill, kill, kill,
    // restore ×3, kill — exercises chained replacements both ways.
    for &restore in
        &[false, false, true, false, true, true, false, false, false, true, true, true, false]
    {
        do_step(restore);
    }
}

/// Weighted churn: every bucket step of `SETW` / `ADDW`, every
/// whole-node `fail_node` union delta, and every multi-bucket restore
/// stays sound (planner `delta_coverage` missed == 0) **and** confined —
/// a resize of one node moves only keys whose old or new bucket belongs
/// to that resize. Each step's (old, new) pair is reconstructed from
/// consecutive seeds (step i's "new" state is step i+1's old state; the
/// last step's is the live router).
#[test]
fn weighted_resize_deltas_cover_observed_movement() {
    use memento::coordinator::membership::NodeSpec;
    use memento::coordinator::router::ChangeSeed;

    let tracers: Vec<u64> = (0..20_000u64).map(memento::hashing::mix::splitmix64_mix).collect();
    let router = Router::new("memento", 12, 240, None).unwrap();

    let verify = |router: &Router, seeds: &[ChangeSeed]| {
        for (i, seed) in seeds.iter().enumerate() {
            let old = seed.old_placement.algo();
            let check = |new_algo: &dyn ConsistentHasher| {
                let rep = audit::delta_coverage(old, new_algo, &seed.delta, &tracers);
                assert_eq!(rep.missed, 0, "stranded movers in step {i}: {rep:?}");
                for &k in tracers.iter().take(4_000) {
                    let (b0, b1) = (old.lookup(k), new_algo.lookup(k));
                    if b0 != b1 {
                        assert!(
                            seed.changed_buckets.contains(&b0)
                                || seed.changed_buckets.contains(&b1),
                            "collateral move {b0}->{b1} outside changed {:?}",
                            seed.changed_buckets
                        );
                    }
                }
            };
            match seeds.get(i + 1) {
                Some(next) => check(next.old_placement.algo()),
                None => router.with_view(|a, _m| check(a)),
            }
        }
    };

    // Grow a founding node to weight 3 (tail growth, 2 bucket steps).
    let n3 = router.with_view(|_a, m| m.node_at(3)).unwrap();
    let (_change, seeds) = router.set_weight_planned(n3, 3).unwrap();
    assert_eq!(seeds.len(), 2);
    verify(&router, &seeds);

    // A weight-2 node joins.
    let ((_buckets, heavy), seeds) =
        router.add_node_weighted_planned(NodeSpec::weighted(2)).unwrap();
    assert_eq!(seeds.len(), 2);
    verify(&router, &seeds);

    // Whole-node failure of the weight-3 node: one atomic change whose
    // delta is the union across its three buckets.
    let (_n, seed) = router.fail_node_planned(n3).unwrap();
    assert_eq!(seed.changed_buckets.len(), 3);
    assert!(!seed.delta.full_scan, "memento multi-removal stays structural");
    verify(&router, std::slice::from_ref(&seed));

    // Shrink the joined node back to weight 1: each drain step's delta
    // is exactly its removed bucket (minimal disruption, Prop. VI.3).
    let (change, seeds) = router.set_weight_planned(heavy, 1).unwrap();
    assert_eq!(change.removed.len(), 1);
    for s in &seeds {
        assert_eq!(s.delta.sources, s.changed_buckets, "shrink delta = the removed bucket");
        assert!(!s.delta.full_scan);
    }
    verify(&router, &seeds);

    // Restore the failed weight-3 node: three bucket steps, each a tight
    // replacement-chain pull.
    let ((_b, restored), seeds) = router.add_node_planned().unwrap();
    assert_eq!(restored, n3);
    assert_eq!(seeds.len(), 3, "restore reattaches the node's full weight");
    for s in &seeds {
        assert!(!s.delta.full_scan, "restores pull through the chain, not a full scan");
    }
    verify(&router, &seeds);
}

/// Algorithms without a structural delta (here: anchor) migrate through
/// the conservative full-scan plan — slower, but still correct and still
/// off the admin path.
#[test]
fn non_memento_algorithms_fall_back_to_full_scan_plans() {
    let router = Router::new("anchor", 8, 80, None).unwrap();
    let svc = Service::new(router);
    for i in 0..400 {
        svc.handle(&format!("PUT a{i} av{i}"));
    }
    let resp = svc.handle("KILL 3");
    assert!(resp.starts_with("KILLED"), "{resp}");
    assert!(
        resp.contains("SOURCES 8"),
        "anchor has no delta override: all 8 old buckets are sources: {resp}"
    );
    for i in 0..400 {
        let r = svc.handle(&format!("GET a{i}"));
        assert!(r.contains(&format!("av{i}")), "a{i}: {r}");
    }
    assert!(svc.migration.wait_idle(Duration::from_secs(10)));
    for i in 0..400 {
        let r = svc.handle(&format!("GET a{i}"));
        assert!(r.contains(&format!("av{i}")), "post-drain a{i}: {r}");
    }
    let stats = svc.handle("STATS");
    assert!(stats.contains("violations=0"), "{stats}");
}

/// Manual-mode pipeline driven directly (no protocol): drain + pull with
/// explicit plans, asserting the moved set equals the planner's set key
/// by key — no collateral movement at the record level.
#[test]
fn executor_moves_exactly_the_planned_records() {
    let router = Router::new("memento", 12, 120, None).unwrap();
    let storage = Arc::new(memento::coordinator::storage::StorageCluster::new());
    let migrator = Migrator::spawn(
        router.clone(),
        storage.clone(),
        MigrationConfig { auto: false, batch_keys: 64, max_inflight: 4 },
    );
    let keys: Vec<u64> = (0..6_000u64).map(memento::hashing::mix::splitmix64_mix).collect();
    for &k in &keys {
        let (_b, n) = router.route(k);
        storage.node(n).put(k, k.to_le_bytes().to_vec());
    }
    // Keys expected to move on KILL 6: exactly the victim's records.
    let victim = router.with_view(|_a, m| m.node_at(6)).unwrap();
    let mut expected: Vec<u64> = storage.node(victim).keys();
    expected.sort_unstable();

    let (node, seed) = router.fail_bucket_planned(6).unwrap();
    let before: Vec<(memento::coordinator::membership::NodeId, usize)> = storage.load_by_node();
    migrator.enqueue(MigrationPlan::from_seed(PlanKind::Drain, node, seed));
    let moved = migrator.run_pending();
    assert_eq!(moved as usize, expected.len());
    // Every expected key is at its new primary; every other node only
    // gained keys (drain targets), never lost one.
    for &k in &expected {
        let (_b, n) = router.route(k);
        assert_eq!(storage.node(n).get(k), Some(k.to_le_bytes().to_vec()));
    }
    for (id, n_before) in before {
        if id != victim {
            assert!(
                storage.node(id).len() >= n_before,
                "survivor {id} lost records during a drain of {victim}"
            );
        }
    }
    assert_eq!(storage.total_records(), keys.len(), "no record lost or duplicated");
}
