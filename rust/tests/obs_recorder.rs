//! Flight-recorder integration drills (own process, so the process-global
//! recorder's accounting can be checked exactly):
//!
//! 1. concurrent writers racing a dumping reader — no torn events leak,
//!    loss is bounded and *exactly* accounted at quiescence, and the
//!    event order across a live epoch publish matches the admin path's
//!    causal order;
//! 2. dump-on-panic — a child process installs the hook, records a
//!    marker event and panics; the parent asserts the recorder tail
//!    reached stderr.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::obs::{self, EventKind};

const WRITERS: usize = 8;
const PER_WRITER: u64 = 40_000;

#[test]
fn recorder_survives_concurrent_writers_and_accounts_for_loss() {
    let rec = obs::recorder();
    let base_total = rec.total_events();

    // Phase 1: hammer the rings from 8 threads while a reader dumps.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let reader = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let rec = obs::recorder();
            let mut dumps = 0u32;
            while dumps < 50 && !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let d = rec.dump(usize::MAX);
                let mut prev_seq = 0u64;
                for e in &d.events {
                    assert!(e.seq > prev_seq, "seqs must be strictly increasing");
                    prev_seq = e.seq;
                    if e.kind == EventKind::BatchDone {
                        assert!(e.a < WRITERS as u64, "torn payload leaked: {e:?}");
                        assert!(e.b < PER_WRITER, "torn payload leaked: {e:?}");
                    }
                }
                dumps += 1;
            }
        })
    };
    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            std::thread::spawn(move || {
                let rec = obs::recorder();
                for i in 0..PER_WRITER {
                    rec.record(EventKind::BatchDone, w as u64, i);
                }
            })
        })
        .collect();
    for t in writers {
        t.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    reader.join().unwrap();

    // Phase 2: quiescent accounting is exact — every event ever recorded
    // is either in the dump or counted as dropped, and nothing is torn.
    let d = rec.dump(usize::MAX);
    assert_eq!(d.torn, 0, "no writer is live; a quiescent dump cannot tear");
    assert!(
        d.total - base_total >= (WRITERS as u64) * PER_WRITER,
        "total {} base {base_total}",
        d.total
    );
    assert!(d.dropped > 0, "320k events must overflow 16x1024 slots");
    assert_eq!(
        d.events.len() as u64 + d.dropped,
        d.total,
        "retained + dropped must account for every event exactly"
    );

    // Phase 3: a real admin sequence journals in causal order. The
    // KILL handler publishes the epoch, enqueues the plan, then records
    // the kill; ADD repeats the pattern at the next epoch.
    let router = Router::new("memento", 8, 80, None).unwrap();
    let s = Service::new(router);
    for i in 0..50 {
        s.handle(&format!("PUT ok{i} ov{i}"));
    }
    assert!(s.handle("KILL 3").starts_with("KILLED"));
    assert!(s.handle("ADD").starts_with("ADDED"));
    assert!(s.migration.wait_idle(std::time::Duration::from_secs(10)));

    let d = rec.dump(usize::MAX);
    let seq_of = |kind: EventKind, a: Option<u64>| -> u64 {
        d.events
            .iter()
            .find(|e| {
                e.kind == kind
                    && match a {
                        Some(want) => e.a == want,
                        None => true,
                    }
            })
            .unwrap_or_else(|| panic!("no {kind:?} a={a:?} event in dump"))
            .seq
    };
    let publish1 = seq_of(EventKind::EpochPublish, Some(1));
    let publish2 = seq_of(EventKind::EpochPublish, Some(2));
    let plan1 = seq_of(EventKind::PlanBegin, Some(1));
    let kill = seq_of(EventKind::NodeKill, None);
    let add = seq_of(EventKind::NodeAdd, None);
    assert!(publish1 < plan1, "the epoch publishes before its plan enqueues");
    assert!(plan1 < kill, "the kill is journaled after its plan");
    assert!(kill < publish2, "epochs are ordered across admin commands");
    assert!(publish2 < add, "the add is journaled after its publish");
}

#[test]
fn dump_on_panic_emits_the_recorder_tail() {
    if std::env::var("MEMENTO_OBS_PANIC_CHILD").is_ok() {
        // Child branch: arm the hook, leave a marker in the journal, die.
        obs::install_panic_hook();
        obs::recorder().record(EventKind::RecoveryStep, 41, 42);
        panic!("armed panic for the dump-on-panic drill");
    }
    let exe = std::env::current_exe().unwrap();
    let out = std::process::Command::new(exe)
        .args([
            "dump_on_panic_emits_the_recorder_tail",
            "--exact",
            "--nocapture",
            "--test-threads=1",
        ])
        .env("MEMENTO_OBS_PANIC_CHILD", "1")
        .output()
        .expect("spawn the panic child");
    assert!(!out.status.success(), "the child must die of its panic");
    let combined = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        combined.contains("flight recorder (dump on panic)"),
        "panic hook banner missing:\n{combined}"
    );
    assert!(combined.contains("recovery_step"), "marker event missing:\n{combined}");
    assert!(combined.contains("a=41 b=42"), "marker payload missing:\n{combined}");
}
