//! Integration: router + batcher + rebalancer + storage working together
//! (no network, no engine — those are covered by integration_service.rs
//! and integration_runtime.rs respectively).

use memento::coordinator::batcher::Batcher;
use memento::coordinator::rebalancer::Rebalancer;
use memento::coordinator::router::Router;
use memento::coordinator::storage::StorageCluster;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::testkit::{forall_noshrink, Config};
use std::sync::Arc;
use std::time::Duration;

fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

#[test]
fn full_lifecycle_disruption_audit() {
    // A long random lifecycle: failures and restores interleaved, the
    // rebalancer must never observe a violation for memento.
    forall_noshrink(
        "router lifecycle audit",
        Config::with_cases(8),
        |rng| (8 + rng.next_below(24) as usize, rng.next_u64()),
        |&(w, seed)| {
            let router = Router::new("memento", w, w * 10, None).map_err(|e| e.to_string())?;
            let reb = Rebalancer::new(&router, 10_000, seed);
            let mut rng = Xoshiro256::new(seed);
            for _ in 0..12 {
                if rng.next_bool(0.6) && router.working() > 2 {
                    let wb = router.with_view(|a, _| a.working_buckets());
                    let b = wb[rng.next_index(wb.len())];
                    router.fail_bucket(b).map_err(|e| e.to_string())?;
                    let s = reb.observe_epoch(&router, &[b]);
                    if s.violations > 0 {
                        return Err(format!("violation after failing {b}"));
                    }
                } else {
                    let (b, _n) = router.add_node().map_err(|e| e.to_string())?;
                    let s = reb.observe_epoch(&router, &[b]);
                    if s.violations > 0 {
                        return Err(format!("violation after adding {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn storage_follows_router_through_failures() {
    let router = Router::new("memento", 12, 120, None).unwrap();
    let storage = StorageCluster::new();
    let ks = keys(3_000, 0x57);
    for &k in &ks {
        let (_b, node) = router.route(k);
        storage.node(node).put(k, k.to_le_bytes().to_vec());
    }
    assert_eq!(storage.total_records(), 3_000);

    // Fail three nodes; migrate each failed node's data per the new routing.
    for bucket in [2u32, 7, 9] {
        let node = router.fail_bucket(bucket).unwrap();
        let r = router.clone();
        storage.migrate_from(node, move |k| r.route(k).1);
    }
    // Every key must be found exactly where the router now points.
    for &k in &ks {
        let (_b, node) = router.route(k);
        assert_eq!(
            storage.node(node).get(k),
            Some(k.to_le_bytes().to_vec()),
            "key {k:#x} lost after migrations"
        );
    }
    assert_eq!(storage.total_records(), 3_000, "no records lost or duplicated");
}

#[test]
fn storage_load_tracks_balance() {
    let router = Router::new("memento", 10, 100, None).unwrap();
    let storage = StorageCluster::new();
    let ks = keys(50_000, 0x77);
    for &k in &ks {
        let (_b, node) = router.route(k);
        storage.node(node).put(k, vec![0]);
    }
    let loads = storage.load_by_node();
    assert_eq!(loads.len(), 10);
    let ideal = 5_000f64;
    for (node, count) in loads {
        let dev = (count as f64 - ideal).abs() / ideal;
        assert!(dev < 0.12, "{node}: {count} records, dev {dev:.3}");
    }
}

#[test]
fn batcher_survives_membership_churn() {
    let router = Router::new("memento", 16, 160, None).unwrap();
    let (batcher, handle) = Batcher::spawn(router.clone(), 128, Duration::from_micros(200));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    // Lookup threads hammer the batcher while the main thread churns
    // membership; all lookups must resolve to working buckets.
    let lookup_threads: Vec<_> = (0..4)
        .map(|t| {
            let h = handle.clone();
            let r = router.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::new(t);
                let mut count = 0u32;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = rng.next_u64();
                    let b = h.lookup(k).expect("batcher alive");
                    // The bucket must have been working at *some* recent
                    // epoch; verify it's a plausible bucket id.
                    assert!((b as usize) < r.with_view(|a, _| a.size()) + 1);
                    count += 1;
                }
                count
            })
        })
        .collect();

    let mut rng = Xoshiro256::new(99);
    for _ in 0..10 {
        std::thread::sleep(Duration::from_millis(5));
        if rng.next_bool(0.5) && router.working() > 4 {
            let wb = router.with_view(|a, _| a.working_buckets());
            let b = wb[rng.next_index(wb.len())];
            let _ = router.fail_bucket(b);
        } else {
            let _ = router.add_node();
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: u32 = lookup_threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(total > 100, "lookups made progress: {total}");
    drop(handle);
    batcher.join();
}

#[test]
fn router_with_every_algorithm() {
    for name in memento::algorithms::ALL_ALGOS {
        let router = Router::new(name, 8, 80, None)
            .unwrap_or_else(|e| panic!("router({name}): {e}"));
        let ks = keys(500, 1);
        for &k in &ks {
            let (b, node) = router.route(k);
            assert!(router.with_view(|a, _| a.is_working(b)), "{name}: non-working bucket");
            assert_eq!(router.with_view(|_, m| m.node_at(b)), Some(node));
        }
        // One failure + one restore, where supported.
        let wb = router.with_view(|a, _| a.working_buckets());
        let can_fail = router.with_view(|a, _| a.supports_random_removal());
        if can_fail {
            router.fail_bucket(wb[wb.len() / 2]).unwrap();
            router.add_node().unwrap();
            assert_eq!(router.working(), 8, "{name}");
        }
    }
}
