//! Integration: the hot-key cache tier end to end — epoch-invalidated
//! cached reads staying fresh across KILL→drain→ADD churn with
//! replication, and single-flight coalescing collapsing a concurrent
//! miss storm into one storage read.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::netserver::{Client, ClientError};
use memento::proto::Request;
use std::sync::{Arc, Barrier};

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so assertions stay line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

const KEYS: usize = 200;

/// Churn drill over TCP with replication=2: warmed cache entries must
/// never serve a stale value across write-through overwrites and
/// KILL/ADD epoch bumps, and no acknowledged write may be lost. The
/// request sequence is single-connection and sequential, so the cache
/// counters are fully deterministic and asserted exactly.
#[test]
fn cached_reads_stay_fresh_across_kill_drain_add_churn() {
    let router = Router::new("memento", 10, 100, None).unwrap();
    let svc = Service::with_replicas(router, 2);
    let server = svc.serve("127.0.0.1:0", 32).unwrap();
    let mut c = Client::connect(&server.addr()).unwrap();
    let cache = svc.cache.as_ref().expect("the hot cache is on by default");

    // Preload, then a fill pass (every key misses into the cache) and a
    // verification pass (every key must now be a cache hit).
    let mut latest: Vec<String> = Vec::new();
    for i in 0..KEYS {
        let v = format!("v0-{i}");
        let r = req(&mut c, &format!("PUT ck{i} {v}"));
        assert!(r.starts_with("OK"), "{r}");
        latest.push(v);
    }
    for pass in 0..2 {
        for i in 0..KEYS {
            let r = req(&mut c, &format!("GET ck{i}"));
            assert!(r.contains(&latest[i]), "pass {pass} ck{i}: {r}");
        }
    }
    let (hits, misses, _) = cache.op_counts();
    assert_eq!(
        (hits, misses),
        (KEYS as u64, KEYS as u64),
        "first pass fills, second pass must be served from cache"
    );

    // Three churn rounds. Each round overwrites a third of the keys
    // (write-through invalidation must beat the cached copy), kills a
    // bucket (epoch bump: every cached entry goes stale at once), reads
    // everything, restores the bucket (second epoch bump), and reads
    // everything again.
    for (round, bucket) in [3u32, 7, 5].into_iter().enumerate() {
        for i in (0..KEYS).filter(|i| i % 3 == round) {
            let v = format!("v{}-{i}", round + 1);
            let r = req(&mut c, &format!("PUT ck{i} {v}"));
            assert!(r.starts_with("OK"), "{r}");
            latest[i] = v;
        }
        let r = req(&mut c, &format!("KILL {bucket}"));
        assert!(r.starts_with("KILLED node-"), "{r}");
        for i in 0..KEYS {
            let r = req(&mut c, &format!("GET ck{i}"));
            assert!(r.contains(&latest[i]), "stale or lost after KILL {bucket}, ck{i}: {r}");
        }
        let r = req(&mut c, "ADD");
        assert!(r.contains(&format!("BUCKET {bucket}")), "{r}");
        for i in 0..KEYS {
            let r = req(&mut c, &format!("GET ck{i}"));
            assert!(r.contains(&latest[i]), "stale or lost after ADD, ck{i}: {r}");
        }
    }
    assert_eq!(req(&mut c, "EPOCH"), "EPOCH 6 WORKING 10");

    // Exact counter bookkeeping: 2 warm passes (1 fill + 1 hit), then
    // per round two full passes that each start right after an epoch
    // bump, so every read is a miss-and-refill.
    let (hits, misses, _) = cache.op_counts();
    assert_eq!(hits, KEYS as u64, "post-bump passes must not hit stale epochs");
    assert_eq!(misses, 7 * KEYS as u64, "fill pass + 6 post-bump passes");

    // The placement audit saw no violations, and CACHESTAT exposes the
    // same counters over the wire.
    let stats = req(&mut c, "STATS");
    assert!(stats.contains("violations=0"), "{stats}");
    let cs = req(&mut c, "CACHESTAT");
    assert!(cs.starts_with("CACHESTAT hits=200 misses=1400 "), "{cs}");
    assert!(cs.contains("invalidations="), "{cs}");
    server.shutdown();
}

/// Single-flight coalescing: 64 threads miss on the same key at the
/// same time; the cache must collapse the storm into exactly one
/// storage read, with every non-leader miss accounted as coalesced.
#[test]
fn concurrent_misses_on_one_key_do_exactly_one_storage_read() {
    let router = Router::new("memento", 8, 80, None).unwrap();
    let svc = Service::new(router);
    let r = svc.handle("PUT hotkey warm");
    assert!(r.starts_with("OK"), "{r}");
    let gets_before: u64 = svc.storage.nodes().iter().map(|(_, n)| n.op_counts().0).sum();

    const READERS: usize = 64;
    let start_line = Arc::new(Barrier::new(READERS));
    let threads: Vec<_> = (0..READERS)
        .map(|_| {
            let svc = svc.clone();
            let start_line = start_line.clone();
            std::thread::spawn(move || {
                start_line.wait();
                let r = svc.handle("GET hotkey");
                assert!(r.contains("warm"), "{r}");
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let gets_after: u64 = svc.storage.nodes().iter().map(|(_, n)| n.op_counts().0).sum();
    assert_eq!(
        gets_after - gets_before,
        1,
        "single-flight must collapse {READERS} concurrent misses into one storage read"
    );
    let cache = svc.cache.as_ref().expect("the hot cache is on by default");
    let (hits, misses, coalesced) = cache.op_counts();
    assert_eq!(hits + misses, READERS as u64, "every GET is exactly one hit or miss");
    assert_eq!(misses, coalesced + 1, "every miss but the flight leader must coalesce");
}
