"""L2: the batched lookup engines as jitted JAX functions.

Composes the L1 Pallas kernels into the computations the rust runtime
executes, plus the pure-jnp histogram used by the balance auditor. These
functions are lowered once by aot.py; python never touches the request
path.
"""

import jax
import jax.numpy as jnp

from .kernels import jump as jump_kernel
from .kernels import memento as memento_kernel
from .kernels import mix64


def jump_lookup(keys, n):
    """Engine: batched Jump lookup → (buckets u32[B], ok u32[B])."""
    b, ok = jump_kernel.jump_batch(keys, n)
    return b, ok


def memento_lookup(keys, n, table):
    """Engine: batched Memento lookup → (buckets u32[B], ok u32[B])."""
    b, ok = memento_kernel.memento_batch(keys, n, table)
    return b, ok


def mix2_stream(keys, seeds):
    """Engine: batched 2-input mixing (diagnostics / key pre-digestion)."""
    return (mix64.mix2_batch(keys, seeds),)


def balance_histogram(buckets, n_buckets: int):
    """Engine: per-bucket key counts (u32[N]) from bucket ids (u32[B]).

    Out-of-range ids (the padding sentinel u32::MAX) fall outside every
    bin and are dropped — pure jnp: XLA fuses the one-hot sum into a
    single scatter-add loop, no Pallas needed for this auxiliary path.
    """
    b = buckets.astype(jnp.uint32)
    counts = jnp.zeros((n_buckets,), dtype=jnp.uint32)
    in_range = b < jnp.uint32(n_buckets)
    idx = jnp.where(in_range, b, jnp.uint32(0)).astype(jnp.int32)
    counts = counts.at[idx].add(in_range.astype(jnp.uint32))
    return (counts,)


# ---------------------------------------------------------------------------
# Pure-jnp references (vectorized, non-Pallas) used by the pytest suite to
# cross-check the kernels at sizes where the exact python-int oracle in
# kernels/ref.py would be too slow.
# ---------------------------------------------------------------------------


def jump_lookup_jnp(keys, n):
    """Vectorized jump via the same masked loop, without pallas_call."""
    from .kernels.common import JUMP_MAX_ITERS
    from .kernels.jump import _jump_body

    keys = keys.astype(jnp.uint64)
    n = n.astype(jnp.int64)
    b0 = jnp.full(keys.shape, -1, dtype=jnp.int64)
    j0 = jnp.zeros(keys.shape, dtype=jnp.int64)
    b, j, _k, _n = jax.lax.fori_loop(0, JUMP_MAX_ITERS, _jump_body, (b0, j0, keys, n))
    return b.astype(jnp.uint32), (j >= n).astype(jnp.uint32)
