# Build-time package: JAX/Pallas kernels + AOT lowering. Never imported at
# request time — the rust binary consumes artifacts/*.hlo.txt only.
