# L1 Pallas kernels. All kernels run with interpret=True: the CPU PJRT
# plugin cannot execute Mosaic custom-calls, and interpret-mode lowering
# produces plain HLO the rust runtime can compile (see DESIGN.md section 5).
