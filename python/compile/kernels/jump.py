"""L1 kernel: batched JumpHash (Lamping & Veach) — Memento's core engine.

Hardware adaptation (DESIGN.md §2): the paper's data-dependent `while`
becomes a fixed-trip masked loop. Every lane carries (b, j, key) state;
converged lanes (j ≥ n) freeze. After JUMP_MAX_ITERS the kernel reports a
per-lane `ok` flag — non-converged lanes are re-resolved by the rust
scalar path, so the result is exact at any bound.

The f64 arithmetic inside matches rust's `as f64` / `as i64` semantics
exactly for the value ranges involved (divisor < 2^31 ⇒ products < 2^62,
below the f64 53-bit mantissa *only* for b+1 < 2^22 — above that the
product rounds identically in both languages because both use IEEE
round-to-nearest for the multiply and then truncate).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common

BLOCK = 2048


def _jump_body(_i, state):
    b, j, key, n = state
    active = j < n
    nb = jnp.where(active, j, b)
    nkey = jnp.where(active, key * common.JUMP_K + np.uint64(1), key)
    ratio = np.float64(2147483648.0) / ((nkey >> np.uint64(33)) + np.uint64(1)).astype(jnp.float64)
    nj = jnp.where(
        active,
        ((nb + 1).astype(jnp.float64) * ratio).astype(jnp.int64),
        j,
    )
    return nb, nj, nkey, n


def jump_walk(keys, n):
    """The masked Jump walk with data-dependent early exit.

    A `while_loop` instead of a fixed-trip `fori_loop`: the block exits as
    soon as EVERY lane converged (perf: E[max-lane iters] ≈ ln n + ln B
    instead of always paying JUMP_MAX_ITERS — see EXPERIMENTS.md §Perf).
    The cap is retained for exactness bookkeeping: lanes still active at
    the cap report ok=0 and take the rust scalar path.
    """
    b0 = jnp.full(keys.shape, -1, dtype=jnp.int64)
    j0 = jnp.zeros(keys.shape, dtype=jnp.int64)

    def cond(state):
        i, b_j_k = state
        _b, j, _k, nn = b_j_k
        return (i < common.JUMP_MAX_ITERS) & jnp.any(j < nn)

    def body(state):
        i, b_j_k = state
        return i + 1, _jump_body(i, b_j_k)

    _i, (b, j, _k, _n) = jax.lax.while_loop(cond, body, (0, (b0, j0, keys, n)))
    return b, j >= n


def _jump_kernel(key_ref, n_ref, b_ref, ok_ref):
    keys = key_ref[...]
    n = n_ref[0].astype(jnp.int64)
    b, ok = jump_walk(keys, n)
    b_ref[...] = b.astype(jnp.uint32)
    ok_ref[...] = ok.astype(jnp.uint32)


def jump_batch(keys, n):
    """Batched jump lookup.

    Args:
      keys: u64[B] pre-digested keys.
      n: u32 scalar bucket count (≥ 1).

    Returns:
      (buckets u32[B], ok u32[B]) — `ok=0` lanes did not converge within
      the iteration bound and must be resolved scalar-side.
    """
    (b,) = keys.shape
    block = min(BLOCK, b)
    assert b % block == 0
    n_arr = jnp.reshape(n.astype(jnp.uint32), (1,))
    return pl.pallas_call(
        _jump_kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),  # broadcast scalar n
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.uint32),
            jax.ShapeDtypeStruct((b,), jnp.uint32),
        ],
        interpret=True,
    )(keys.astype(jnp.uint64), n_arr)
