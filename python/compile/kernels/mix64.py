"""L1 kernel: SplitMix64 mixing over key blocks.

The `hash(key, b)` of Alg. 4 line 5, batched. Elementwise over the batch —
pure VPU work, no gathers. BlockSpec tiles the batch into VMEM-sized blocks
(`BLOCK` u64 lanes = 8·BLOCK bytes per buffer; at 2048 lanes the working
set is 48 KiB, far under the ~16 MiB VMEM budget — see DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .common import mix2  # noqa: F401  (re-export for model.py)

BLOCK = 2048


def _mix2_kernel(key_ref, seed_ref, o_ref):
    o_ref[...] = common.mix2(key_ref[...], seed_ref[...])


def mix2_batch(keys, seeds):
    """Pallas-batched mix2 over equal-shaped u64 arrays."""
    (b,) = keys.shape
    block = min(BLOCK, b)
    assert b % block == 0, "batch must be a multiple of the block size"
    return pl.pallas_call(
        _mix2_kernel,
        grid=(b // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint64),
        interpret=True,
    )(keys.astype(jnp.uint64), seeds.astype(jnp.uint64))
