"""L1 kernel: batched MementoHash lookup (paper Alg. 4) against a dense
replacement table.

Hardware adaptation (DESIGN.md §2):
* The Θ(r) replacement hash table becomes a Θ(n) dense array
  `table[b] = c` (sentinel = working) — the SIMD-friendly freeze, rebuilt
  per membership epoch by the rust coordinator, never on the lookup path.
* Both nested loops of Alg. 4 run as fixed-trip masked loops
  (OUTER_MAX_ITERS × INNER_MAX_ITERS); per-lane `ok` flags mark lanes that
  converged. Non-converged lanes (astronomically rare at the configured
  bounds — E[iters] ≈ ln(n/w) per Prop. VII.1/2) are re-resolved by the
  rust scalar path, keeping the engine bit-exact.
* The table rides whole in each block's VMEM window (u32[N]; 256 KiB at
  N = 65536 — within budget, see DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import common
from .jump import jump_walk

BLOCK = 2048


def _chain_walk(d, w_b, table, active):
    """Alg. 4 lines 7-9: chase the replacement chain while u ≥ w_b.

    Early-exit while_loop: a stable epoch pays ONE gather here, not
    INNER_MAX_ITERS (EXPERIMENTS.md §Perf).
    """

    def cond(state):
        i, d, follow_any = state
        del d
        return (i < common.INNER_MAX_ITERS) & follow_any

    def step(d):
        u = jnp.take(table, d.astype(jnp.int64), mode="clip")
        follow = active & (u != common.NO_REPLACEMENT) & (u >= w_b)
        return jnp.where(follow, u, d), follow

    def body(state):
        i, d, _fa = state
        nd, follow = step(d)
        return i + 1, nd, jnp.any(follow)

    d0, follow0 = step(d)
    _i, d, _fa = jax.lax.while_loop(cond, body, (1, d0, jnp.any(follow0)))
    # If any lane still wants to follow, the bound was hit: poison it.
    u = jnp.take(table, d.astype(jnp.int64), mode="clip")
    still = active & (u != common.NO_REPLACEMENT) & (u >= w_b)
    return d, still


def _outer_step(b, inner_bad, table, keys):
    c = jnp.take(table, b.astype(jnp.int64), mode="clip")
    active = c != common.NO_REPLACEMENT
    w_b = c
    # Alg. 4 lines 5-6: rehash into [0, w_b). w_b ≥ 1 for any replacement
    # (the cluster is never emptied); guard the inactive lanes anyway.
    h = common.mix2(keys, b.astype(jnp.uint64))
    safe_w = jnp.where(active, w_b, np.uint32(1)).astype(jnp.uint64)
    d = (h % safe_w).astype(jnp.uint32)
    d, still = _chain_walk(d, w_b, table, active)
    inner_bad = inner_bad | still
    b = jnp.where(active, d, b)
    # A lane is settled once its bucket is working.
    settled = jnp.take(table, b.astype(jnp.int64), mode="clip") == common.NO_REPLACEMENT
    return b, inner_bad, settled


def _memento_kernel(key_ref, n_ref, table_ref, b_ref, ok_ref):
    keys = key_ref[...]
    table = table_ref[...]
    n = n_ref[0].astype(jnp.int64)

    # Phase 1 — Alg. 4 line 2: Jump over the full b-array (early exit).
    jb, jump_ok = jump_walk(keys, n)
    b = jb.astype(jnp.uint32)

    # Phase 2 — the nested replacement loops, early-exit while_loop:
    # a stable epoch costs ONE gather; E[iters] ≈ ln(n/w) otherwise
    # (Prop. VII.1).
    inner_bad0 = jnp.zeros(keys.shape, dtype=bool)
    settled0 = jnp.take(table, b.astype(jnp.int64), mode="clip") == common.NO_REPLACEMENT

    def cond(state):
        i, _b, _bad, settled = state
        return (i < common.OUTER_MAX_ITERS) & ~jnp.all(settled)

    def body(state):
        i, b, bad, _settled = state
        nb, nbad, settled = _outer_step(b, bad, table, keys)
        return i + 1, nb, nbad, settled

    _i, b, inner_bad, settled = jax.lax.while_loop(
        cond, body, (0, b, inner_bad0, settled0)
    )
    b_ref[...] = b
    ok_ref[...] = (jump_ok & settled & ~inner_bad).astype(jnp.uint32)


def memento_batch(keys, n, table):
    """Batched Memento lookup.

    Args:
      keys: u64[B] pre-digested keys.
      n: u32 scalar b-array size (Def. VI.1).
      table: u32[N] dense replacement table, N ≥ n, padded with
        NO_REPLACEMENT.

    Returns:
      (buckets u32[B], ok u32[B]).
    """
    (bsz,) = keys.shape
    (tsz,) = table.shape
    block = min(BLOCK, bsz)
    assert bsz % block == 0
    n_arr = jnp.reshape(n.astype(jnp.uint32), (1,))
    return pl.pallas_call(
        _memento_kernel,
        grid=(bsz // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tsz,), lambda i: (0,)),  # whole table per block
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz,), jnp.uint32),
            jax.ShapeDtypeStruct((bsz,), jnp.uint32),
        ],
        interpret=True,
    )(keys.astype(jnp.uint64), n_arr, table.astype(jnp.uint32))
