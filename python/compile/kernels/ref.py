"""Pure-python correctness oracles (exact integer arithmetic, no jax).

These mirror the rust scalar implementations line-for-line and are the
ground truth the Pallas kernels are tested against in python/tests/.
"""

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15
MIX_A = 0xBF58476D1CE4E5B9
MIX_B = 0x94D049BB133111EB
SEED_FOLD = 0xA24BAED4963EE407
JUMP_K = 2862933555777941757
NO_REPLACEMENT = 0xFFFFFFFF


def splitmix64(z: int) -> int:
    """Twin of rust mix.rs::splitmix64_mix."""
    z = (z + GOLDEN) & MASK64
    z = ((z ^ (z >> 30)) * MIX_A) & MASK64
    z = ((z ^ (z >> 27)) * MIX_B) & MASK64
    return z ^ (z >> 31)


def mix2(key: int, seed: int) -> int:
    """Twin of rust mix.rs::mix2."""
    return splitmix64(key ^ ((seed * SEED_FOLD) & MASK64))


def jump_hash(key: int, n: int) -> int:
    """Lamping & Veach, exactly as rust algorithms::jump_hash.

    The float math is done through python floats (IEEE f64), matching the
    rust `as f64` / `as i64` (truncating) semantics for the value ranges
    involved (b+1 ≤ 2^31, divisor ≤ 2^31: products stay < 2^62, exact).
    """
    assert n >= 1
    b, j = -1, 0
    while j < n:
        b = j
        key = (key * JUMP_K + 1) & MASK64
        j = int((b + 1) * (float(1 << 31) / float((key >> 33) + 1)))
    return b


def jump_iters(key: int, n: int) -> int:
    """Number of loop iterations jump_hash makes (for bound validation)."""
    iters, b, j = 0, -1, 0
    while j < n:
        iters += 1
        b = j
        key = (key * JUMP_K + 1) & MASK64
        j = int((b + 1) * (float(1 << 31) / float((key >> 33) + 1)))
    return iters


class MementoRef:
    """Reference MementoHash (paper Alg. 1-4), exact twin of memento.rs."""

    def __init__(self, n: int):
        assert n >= 1
        self.n = n
        self.last_removed = n
        self.repl: dict[int, tuple[int, int]] = {}

    @property
    def working(self) -> int:
        return self.n - len(self.repl)

    def is_working(self, b: int) -> bool:
        return b < self.n and b not in self.repl

    def remove(self, b: int) -> None:
        assert self.is_working(b), f"bucket {b} is not working"
        assert self.working > 1, "cannot empty the cluster"
        if not self.repl and b == self.n - 1:
            self.n -= 1
            self.last_removed = self.n
        else:
            w = self.working
            self.repl[b] = (w - 1, self.last_removed)
            self.last_removed = b

    def add(self) -> int:
        if not self.repl:
            b = self.n
            self.n += 1
            self.last_removed = self.n
            return b
        b = self.last_removed
        _c, p = self.repl.pop(b)
        self.last_removed = p if self.repl else self.n
        return b

    def lookup(self, key: int) -> int:
        b = jump_hash(key, self.n)
        while b in self.repl:
            w_b = self.repl[b][0]
            d = mix2(key, b) % w_b
            while d in self.repl and self.repl[d][0] >= w_b:
                d = self.repl[d][0]
            b = d
        return b

    def dense_table(self, pad_to: int | None = None) -> list[int]:
        """table[b] = c for replaced buckets, NO_REPLACEMENT otherwise."""
        size = pad_to if pad_to is not None else self.n
        assert size >= self.n
        t = [NO_REPLACEMENT] * size
        for b, (c, _p) in self.repl.items():
            t[b] = c
        return t
