"""Shared constants + x64 setup for the L1 kernels.

Every constant here has an exact twin in the rust scalar path
(rust/src/hashing/mix.rs, rust/src/algorithms/mod.rs). The integration test
`rust/tests/integration_runtime.rs` asserts bit-identical streams across the
language boundary — do not change one side without the other.
"""

import jax

# 64-bit integers are mandatory: keys are u64 and the Jump LCG wraps mod 2^64.
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402,F401  (after x64 flag)

# SplitMix64 (Stafford mix13) constants — mix.rs.
GOLDEN = np.uint64(0x9E3779B97F4A7C15)
MIX_A = np.uint64(0xBF58476D1CE4E5B9)
MIX_B = np.uint64(0x94D049BB133111EB)
SEED_FOLD = np.uint64(0xA24BAED4963EE407)

# Jump LCG multiplier (Lamping & Veach) — algorithms/mod.rs.
JUMP_K = np.uint64(2862933555777941757)

# Dense replacement-table sentinel — algorithms/memento.rs NO_REPLACEMENT.
NO_REPLACEMENT = np.uint32(0xFFFFFFFF)

# Loop bounds for the masked SIMD adaptation (DESIGN.md §2). Lanes that
# exceed a bound report ok=0 and are re-resolved by the rust scalar path,
# so these bound *throughput*, not correctness.
JUMP_MAX_ITERS = 64   # covers n ≤ 2^32: E[iters] = ln(n) ≈ 22, p(>64) ≈ 0
OUTER_MAX_ITERS = 16  # Memento external loop: E ≈ ln(n/w) (Prop. VII.1)
INNER_MAX_ITERS = 32  # Memento chain walk: E ≈ ln(n/w) (Prop. VII.2)


def splitmix64(z):
    """The SplitMix64 finalizer over uint64 arrays (twin: mix.rs::splitmix64_mix)."""
    z = z + GOLDEN
    z = (z ^ (z >> np.uint64(30))) * MIX_A
    z = (z ^ (z >> np.uint64(27))) * MIX_B
    return z ^ (z >> np.uint64(31))


def mix2(key, seed):
    """Two-input mixer used as Alg. 4's `hash(key, b)` (twin: mix.rs::mix2)."""
    return splitmix64(key ^ (seed * SEED_FOLD))
