"""TPU resource estimator for the L1 kernels (DESIGN.md §Perf).

Pallas runs interpret-mode on CPU here (the image has no TPU), so
real-hardware performance is *estimated* from the BlockSpec geometry:
VMEM footprint per block, bytes streamed per key, and expected loop trip
counts (the paper's Prop. VII.1/2 expectations + the Jump ln(n) walk).
`python -m compile.estimate` prints the table recorded in EXPERIMENTS.md.

Model (v4-lite-ish single core, round numbers):
  VMEM budget   16 MiB
  HBM bandwidth 400 GB/s effective
  VPU           8 lanes × 128 sublanes × ~940 MHz ≈ 1e12 simple ops/s
"""

import math
from dataclasses import dataclass

VMEM_BUDGET = 16 * 1024 * 1024
HBM_GBPS = 400e9
VPU_OPS = 1.0e12

# Ops per loop iteration (counted from the kernel bodies).
JUMP_OPS_PER_ITER = 8  # mul, add, shift, add, div, mul, trunc, select
MEMENTO_OUTER_OPS = 14  # gather, cmp, mix(6), mod, selects
MEMENTO_INNER_OPS = 5  # gather, 2 cmp, and, select


@dataclass
class KernelEstimate:
    name: str
    block: int
    table: int
    vmem_bytes: int
    hbm_bytes_per_key: float
    expected_iters: float
    est_ns_per_key_compute: float
    est_ns_per_key_hbm: float

    @property
    def bound(self) -> str:
        return "HBM" if self.est_ns_per_key_hbm >= self.est_ns_per_key_compute else "VPU"

    @property
    def est_ns_per_key(self) -> float:
        return max(self.est_ns_per_key_hbm, self.est_ns_per_key_compute)


def jump_estimate(block: int, n: int) -> KernelEstimate:
    # State: keys u64 + b,j i64 + out u32×2 per lane.
    vmem = block * (8 + 8 + 8 + 4 + 4)
    iters = math.log(max(n, 2)) + math.log(block)  # E[max over lanes] approx
    compute = iters * JUMP_OPS_PER_ITER / VPU_OPS * 1e9
    hbm = (8 + 4) / HBM_GBPS * 1e9  # stream key in, bucket out
    return KernelEstimate("jump", block, 0, vmem, 12.0, iters, compute, hbm)


def memento_estimate(block: int, table: int, n: int, w: int) -> KernelEstimate:
    vmem = block * (8 + 8 + 8 + 4 + 4 + 4) + table * 4
    lnr = math.log(max(n, 2) / max(w, 1)) if n > w else 0.0
    jump_iters = math.log(max(n, 2)) + math.log(block)
    outer = 1.0 + lnr  # Prop. VII.1 bound (+1 for the settled check)
    inner = 1.0 + lnr  # Prop. VII.2
    ops = (
        jump_iters * JUMP_OPS_PER_ITER
        + outer * MEMENTO_OUTER_OPS
        + outer * inner * MEMENTO_INNER_OPS
    )
    compute = ops / VPU_OPS * 1e9
    # Keys stream from HBM; the table is VMEM-resident per epoch.
    hbm = (8 + 4) / HBM_GBPS * 1e9
    return KernelEstimate(
        f"memento(n={n},w={w})", block, table, vmem, 12.0, outer * inner, compute, hbm
    )


def main() -> None:
    rows = [
        jump_estimate(2048, 10**6),
        memento_estimate(2048, 4096, 4000, 4000),
        memento_estimate(2048, 16384, 10**4, 8 * 10**3),
        memento_estimate(2048, 131072, 10**5, 3.5 * 10**4),
        memento_estimate(2048, 131072, 10**5, 10**4),
    ]
    hdr = f"{'kernel':<26}{'block':>6}{'table':>8}{'VMEM':>10}{'E[iter]':>9}{'ns/key':>8}  bound"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        assert r.vmem_bytes < VMEM_BUDGET, f"{r.name} exceeds VMEM budget"
        print(
            f"{r.name:<26}{r.block:>6}{r.table:>8}{r.vmem_bytes/1024:>9.0f}K"
            f"{r.expected_iters:>9.1f}{r.est_ns_per_key:>8.3f}  {r.bound}"
        )
    print(
        "\nAll variants fit VMEM with ≥25x headroom. The kernels are VPU-bound\n"
        "(~0.15-0.25 ns/key of sequential-loop vector work vs ~0.03 ns/key of\n"
        "HBM streaming): the serial Jump walk dominates, so double-buffering\n"
        "key blocks fully hides HBM latency and projected TPU throughput is\n"
        "~4-7 G lookups/s/core — ≈400-600x the measured scalar CPU path,\n"
        "consistent with the paper's 'runs at CPU speed' framing for Jump\n"
        "scaled to a vector unit."
    )


if __name__ == "__main__":
    main()
