"""AOT lowering: JAX → HLO **text** → artifacts/ for the rust runtime.

HLO text (not `serialize()`d protos) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming (consumed by rust/src/runtime/artifacts.rs):
  jump_b{B}.hlo.txt            — jump_lookup   (keys u64[B], n u32[])
  memento_b{B}_n{N}.hlo.txt    — memento_lookup(keys u64[B], n u32[], table u32[N])
  hist_b{B}_n{N}.hlo.txt       — balance_histogram(buckets u32[B]) → u32[N]

Variant matrix: one jump batch size, three memento table sizes (the engine
picks the smallest table ≥ the live cluster's n). Compile time scales with
the variant count; the defaults keep `make artifacts` under a minute.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Batch size of every engine dispatch (rust pads tails; multiple of the
# kernels' BLOCK).
BATCH = 4096

# Dense-table variants: the engine picks the smallest ≥ n.
MEMENTO_TABLES = (4096, 16384, 131072)
HIST_TABLES = (4096,)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_jump(batch: int) -> str:
    keys = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    n = jax.ShapeDtypeStruct((), jnp.uint32)
    return to_hlo_text(jax.jit(model.jump_lookup).lower(keys, n))


def lower_memento(batch: int, table: int) -> str:
    keys = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    n = jax.ShapeDtypeStruct((), jnp.uint32)
    tbl = jax.ShapeDtypeStruct((table,), jnp.uint32)
    return to_hlo_text(jax.jit(model.memento_lookup).lower(keys, n, tbl))


def lower_hist(batch: int, table: int) -> str:
    buckets = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    fn = functools.partial(model.balance_histogram, n_buckets=table)
    return to_hlo_text(jax.jit(fn).lower(buckets))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument(
        "--tables",
        type=lambda s: tuple(int(x) for x in s.split(",")),
        default=MEMENTO_TABLES,
        help="comma-separated memento table sizes",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    def emit(name: str, text: str) -> None:
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    emit(f"jump_b{args.batch}.hlo.txt", lower_jump(args.batch))
    for table in args.tables:
        emit(f"memento_b{args.batch}_n{table}.hlo.txt", lower_memento(args.batch, table))
    for table in HIST_TABLES:
        emit(f"hist_b{args.batch}_n{table}.hlo.txt", lower_hist(args.batch, table))


if __name__ == "__main__":
    main()
