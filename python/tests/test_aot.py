"""AOT lowering tests: the HLO text artifacts have the right entry shapes
and are re-derivable (the rust side further validates by compiling and
executing them — tests/integration_runtime.rs)."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_jump_lowering_contains_shapes():
    text = aot.lower_jump(4096)
    assert "u64[4096]" in text, "keys input missing"
    assert "u32[]" in text, "scalar n missing"
    assert "u32[4096]" in text, "bucket output missing"
    # Tuple root with two outputs.
    assert "(u32[4096]" in text


def test_memento_lowering_contains_shapes():
    text = aot.lower_memento(4096, 16384)
    assert "u64[4096]" in text
    assert "u32[16384]" in text, "dense table input missing"
    assert "u32[]" in text


def test_hist_lowering_contains_shapes():
    text = aot.lower_hist(4096, 4096)
    assert "u32[4096]" in text


def test_lowering_is_deterministic():
    assert aot.lower_jump(1024) == aot.lower_jump(1024)


def test_model_functions_execute_after_lowering_roundtrip():
    # The lowered computation and the eager function agree (jax executes
    # the same jaxpr; this guards against signature drift in aot.py).
    ks = np.random.default_rng(0).integers(0, 2**64, 1024, dtype=np.uint64)
    b_eager, ok_eager = model.jump_lookup(jnp.asarray(ks), jnp.uint32(777))
    import jax

    jitted = jax.jit(model.jump_lookup)
    b_jit, ok_jit = jitted(jnp.asarray(ks), jnp.uint32(777))
    np.testing.assert_array_equal(np.asarray(b_eager), np.asarray(b_jit))
    np.testing.assert_array_equal(np.asarray(ok_eager), np.asarray(ok_jit))
