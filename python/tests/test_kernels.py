"""Pallas kernels vs the exact python-int oracle — the core L1 correctness
signal, including hypothesis sweeps over shapes, sizes and removal
patterns."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import common, ref
from compile.kernels.jump import jump_batch
from compile.kernels.memento import memento_batch
from compile.kernels.mix64 import mix2_batch


def rand_keys(n, seed=0):
    return np.random.default_rng(seed).integers(0, 2**64, n, dtype=np.uint64)


# ---------------------------------------------------------------- mix2 ----


def test_mix2_matches_oracle():
    ks = rand_keys(256, 1)
    seeds = rand_keys(256, 2)
    out = np.asarray(mix2_batch(jnp.asarray(ks), jnp.asarray(seeds)))
    for k, s, o in zip(ks, seeds, out):
        assert int(o) == ref.mix2(int(k), int(s))


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**64 - 1),
    st.integers(min_value=0, max_value=2**64 - 1),
)
def test_mix2_hypothesis(key, seed):
    out = np.asarray(
        mix2_batch(
            jnp.full((8,), key, dtype=jnp.uint64), jnp.full((8,), seed, dtype=jnp.uint64)
        )
    )
    assert all(int(o) == ref.mix2(key, seed) for o in out)


# ---------------------------------------------------------------- jump ----


@pytest.mark.parametrize("n", [1, 2, 3, 10, 1000, 10**6, 2**31 - 1])
def test_jump_matches_oracle(n):
    ks = rand_keys(512, n % 97)
    b, ok = jump_batch(jnp.asarray(ks), jnp.uint32(n))
    b, ok = np.asarray(b), np.asarray(ok)
    assert ok.all(), f"non-converged lanes at n={n}: {int(ok.sum())}/512"
    for k, got in zip(ks, b):
        assert int(got) == ref.jump_hash(int(k), n)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=2**31 - 1),
    st.integers(min_value=0, max_value=2**32),
)
def test_jump_hypothesis(n, seed):
    ks = rand_keys(64, seed)
    b, ok = jump_batch(jnp.asarray(ks), jnp.uint32(n))
    assert np.asarray(ok).all()
    for k, got in zip(ks, np.asarray(b)):
        assert int(got) == ref.jump_hash(int(k), n)


def test_jump_iteration_bound_is_generous():
    # The paper's complexity argument: E[iters] = O(ln n). Empirically the
    # p100 over 20k keys at n=2^31 must sit far below JUMP_MAX_ITERS.
    worst = max(ref.jump_iters(int(k), 2**31 - 1) for k in rand_keys(20000, 3))
    assert worst < common.JUMP_MAX_ITERS - 10, worst


# ------------------------------------------------------------- memento ----


def build_ref(w, removals, seed):
    m = ref.MementoRef(w)
    rng = np.random.default_rng(seed)
    for _ in range(removals):
        working = [b for b in range(m.n) if m.is_working(b)]
        if len(working) <= 1:
            break
        m.remove(int(rng.choice(working)))
    return m


@pytest.mark.parametrize(
    "w,removals",
    [(10, 0), (10, 5), (100, 30), (100, 90), (1000, 650), (2048, 500), (4000, 3600)],
)
def test_memento_matches_oracle(w, removals):
    m = build_ref(w, removals, seed=w + removals)
    pad = max(64, 1 << (m.n - 1).bit_length())
    table = jnp.asarray(np.array(m.dense_table(pad_to=pad), dtype=np.uint32))
    ks = rand_keys(512, removals)
    b, ok = memento_batch(jnp.asarray(ks), jnp.uint32(m.n), table)
    b, ok = np.asarray(b), np.asarray(ok)
    converged = int(ok.sum())
    assert converged >= 510, f"convergence too low: {converged}/512"
    for k, got, o in zip(ks, b, ok):
        if o:
            assert int(got) == m.lookup(int(k)), f"w={w} removals={removals} key={k}"


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=500),
    st.floats(min_value=0.0, max_value=0.9),
    st.integers(min_value=0, max_value=2**31),
)
def test_memento_hypothesis(w, frac, seed):
    m = build_ref(w, int(w * frac), seed)
    pad = max(64, 1 << (m.n - 1).bit_length())
    table = jnp.asarray(np.array(m.dense_table(pad_to=pad), dtype=np.uint32))
    ks = rand_keys(64, seed)
    b, ok = memento_batch(jnp.asarray(ks), jnp.uint32(m.n), table)
    for k, got, o in zip(ks, np.asarray(b), np.asarray(ok)):
        if o:
            assert int(got) == m.lookup(int(k))


def test_memento_ok_flag_is_meaningful():
    # A stable cluster must fully converge (jump bound is the only limit).
    m = ref.MementoRef(1000)
    table = jnp.asarray(np.array(m.dense_table(pad_to=1024), dtype=np.uint32))
    ks = rand_keys(2048, 9)
    _b, ok = memento_batch(jnp.asarray(ks), jnp.uint32(1000), table)
    assert np.asarray(ok).all()


def test_memento_never_returns_removed_bucket_when_ok():
    m = build_ref(300, 200, seed=7)
    removed = set(m.repl)
    table = jnp.asarray(np.array(m.dense_table(pad_to=512), dtype=np.uint32))
    ks = rand_keys(2048, 8)
    b, ok = memento_batch(jnp.asarray(ks), jnp.uint32(m.n), table)
    for got, o in zip(np.asarray(b), np.asarray(ok)):
        if o:
            assert int(got) not in removed
            assert int(got) < m.n


# ----------------------------------------------------------- histogram ----


def test_histogram_matches_numpy():
    buckets = np.random.default_rng(0).integers(0, 64, 4096, dtype=np.uint32)
    (h,) = model.balance_histogram(jnp.asarray(buckets), 64)
    np.testing.assert_array_equal(np.asarray(h), np.bincount(buckets, minlength=64))


def test_histogram_drops_out_of_range():
    buckets = np.array([0, 1, 63, 64, 2**32 - 1], dtype=np.uint32)
    (h,) = model.balance_histogram(jnp.asarray(buckets), 64)
    h = np.asarray(h)
    assert h.sum() == 3
    assert h[0] == 1 and h[1] == 1 and h[63] == 1
