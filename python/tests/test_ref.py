"""The pure-python oracle itself is tested against the paper's worked
examples (the same ones the rust unit tests pin), so both language's
implementations are anchored to the same ground truth."""

from compile.kernels import ref


def test_paper_example_section_v_b():
    m = ref.MementoRef(10)
    m.remove(9)
    assert m.n == 9 and not m.repl
    m.remove(5)
    assert m.repl[5] == (8, 9)
    m.remove(1)
    assert m.repl[1] == (7, 5)
    assert m.working == 7
    assert m.last_removed == 1


def test_paper_example_fig13():
    m = ref.MementoRef(6)
    for b in (0, 3, 5):
        m.remove(b)
    assert m.repl == {0: (5, 6), 3: (4, 0), 5: (3, 3)}
    working = {b for b in range(6) if m.is_working(b)}
    assert working == {1, 2, 4}
    for k in range(5000):
        assert m.lookup(ref.splitmix64(k)) in working


def test_add_restores_lifo():
    m = ref.MementoRef(6)
    for b in (0, 3, 5):
        m.remove(b)
    assert m.add() == 5
    assert m.add() == 3
    assert m.add() == 0
    assert not m.repl
    assert m.add() == 6  # tail growth
    assert m.n == 7


def test_lifo_equivalence_with_jump():
    m = ref.MementoRef(64)
    keys = [ref.splitmix64(k) for k in range(2000)]
    for k in keys:
        assert m.lookup(k) == ref.jump_hash(k, 64)
    for tail in range(63, 33, -1):
        m.remove(tail)
    assert not m.repl
    for k in keys:
        assert m.lookup(k) == ref.jump_hash(k, 34)


def test_minimal_disruption():
    m = ref.MementoRef(20)
    keys = [ref.splitmix64(k) for k in range(20000)]
    before = [m.lookup(k) for k in keys]
    m.remove(7)
    for k, old in zip(keys, before):
        new = m.lookup(k)
        if old != 7:
            assert new == old
        else:
            assert new != 7 and m.is_working(new)


def test_balance_after_removals():
    m = ref.MementoRef(30)
    for b in (3, 17, 8, 22, 1, 29, 14, 6, 19, 27):
        m.remove(b)
    counts: dict[int, int] = {}
    n_keys = 100_000
    for k in range(n_keys):
        b = m.lookup(ref.splitmix64(k))
        counts[b] = counts.get(b, 0) + 1
    ideal = n_keys / m.working
    assert len(counts) == m.working
    for b, c in counts.items():
        assert abs(c - ideal) / ideal < 0.12, (b, c, ideal)


def test_dense_table_roundtrip():
    m = ref.MementoRef(12)
    for b in (2, 7, 4):
        m.remove(b)
    t = m.dense_table(pad_to=16)
    assert len(t) == 16
    for b in range(12):
        if b in m.repl:
            assert t[b] == m.repl[b][0]
        else:
            assert t[b] == ref.NO_REPLACEMENT
    assert all(x == ref.NO_REPLACEMENT for x in t[12:])


def test_jump_growth_property():
    for k in (1, 42, 0xDEADBEEF):
        key = ref.splitmix64(k)
        for n in range(1, 200):
            b1 = ref.jump_hash(key, n)
            b2 = ref.jump_hash(key, n + 1)
            assert b2 == b1 or b2 == n
