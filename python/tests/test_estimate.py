"""The TPU estimator must agree with the compiled variant geometry."""

from compile import aot, estimate


def test_all_variants_fit_vmem():
    for table in aot.MEMENTO_TABLES:
        e = estimate.memento_estimate(2048, table, table, max(table // 2, 1))
        assert e.vmem_bytes < estimate.VMEM_BUDGET


def test_iteration_model_tracks_removals():
    light = estimate.memento_estimate(2048, 131072, 10**5, 9 * 10**4)
    heavy = estimate.memento_estimate(2048, 131072, 10**5, 10**4)
    assert heavy.expected_iters > light.expected_iters
    assert heavy.est_ns_per_key >= light.est_ns_per_key


def test_jump_estimate_monotone_in_n():
    small = estimate.jump_estimate(2048, 10**3)
    big = estimate.jump_estimate(2048, 10**6)
    assert big.expected_iters > small.expected_iters


def test_kernels_are_vpu_bound_with_hbm_hidden():
    # The DESIGN.md §Perf claim: the serial loop work dominates streaming,
    # so key-block double-buffering fully hides HBM latency.
    for e in [
        estimate.jump_estimate(2048, 10**6),
        estimate.memento_estimate(2048, 131072, 10**5, 3 * 10**4),
    ]:
        assert e.bound == "VPU", f"{e.name} unexpectedly {e.bound}-bound"
        assert e.est_ns_per_key_hbm < e.est_ns_per_key_compute
