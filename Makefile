# Convenience targets. The rust crate needs none of these — `cargo build`
# is dependency-free; `artifacts` is only for the optional PJRT path.

.PHONY: build test bench artifacts doc fmt clippy loadgen ci perf-smoke obs-smoke conn-smoke crash-drill cluster-smoke refresh-baselines

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Measure the service under fire: open-loop Zipf traffic with
# coordinated-omission-corrected latency percentiles while nodes fail and
# recover mid-run (see EXPERIMENTS.md §Service under load).
loadgen:
	cargo run --release -- loadgen --mode open --workload zipf --churn incremental

# Mirror of the ci.yml `rust` job, step for step: one command to
# reproduce CI locally before pushing.
ci:
	cargo build --release
	cargo build --release --features pjrt
	cargo test -q
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Mirror of the ci.yml `perf-smoke` job: duration-bounded closed-loop
# loadgen + the router scaling sweep, gated against the committed
# baseline (fails on a >2x throughput regression).
perf-smoke:
	cargo run --release -- loadgen --mode closed --workload uniform \
	  --churn stable --threads 8 --duration 2 --no-csv \
	  --json BENCH_loadgen_smoke.json
	cargo bench --bench bench_router_scaling
	cargo bench --bench bench_migration
	cargo bench --bench bench_weighted
	cargo bench --bench bench_wal
	cargo bench --bench bench_obs
	cargo bench --bench bench_conn
	cargo bench --bench bench_hotset
	python3 scripts/perf_compare.py --current BENCH_router_scaling.json \
	  --loadgen BENCH_loadgen_smoke.json --migration BENCH_migration.json \
	  --weighted BENCH_weighted.json --wal BENCH_wal.json \
	  --obs BENCH_obs.json --conn BENCH_conn.json \
	  --hotset BENCH_hotset.json --baseline ci/perf-baseline.json

# Mirror of the ci.yml `conn-smoke` step: 1024 open-loop binary
# connections (8 workers x 128 conns) against the event-driven
# netserver, with a hard process-wide thread ceiling — connection count
# must be a poller registration count, not a thread count.
conn-smoke:
	cargo run --release -- loadgen --mode open --rate 20000 \
	  --workload uniform --churn stable --threads 8 --conns 128 \
	  --target tcp --proto binary --duration 2 --no-csv \
	  --assert-max-threads 64

# Mirror of the ci.yml `obs-smoke` step: a short churny loadgen run that
# writes the METRICS exposition to a file, validated by a strict
# stdlib-only scraper (scripts/check_exposition.py).
obs-smoke:
	cargo run --release -- loadgen --mode closed --workload uniform \
	  --churn oneshot --threads 4 --duration 1 --no-csv \
	  --expose exposition.txt
	python3 scripts/check_exposition.py exposition.txt

# Mirror of the ci.yml `crash-drill` job: kill the service at each of
# the four crash sites across 8 fixed seeds, recover, and fail on any
# acked-write loss or stranded mover. A failing drill prints its seed;
# reproduce one with:
#   cargo run --release -- crashdrill --site <site> --seed <seed>
crash-drill:
	cargo run --release -- crashdrill --seeds 8

# Mirror of the ci.yml `cluster-smoke` job: a real multi-process cluster
# (each node its own `memento node` child) under live write load, one
# SIGKILL crash and one socket partition on schedule. The heartbeat
# detector must confirm each fault (driving KILLN + drain), the node
# must rejoin via ADD + snapshot install, and every acked write must
# read back; the drill's JSON is then gated against the baseline.
cluster-smoke:
	cargo run --release -- cluster-drill --nodes 4 --faults crash,partition \
	  --json BENCH_cluster.json
	python3 scripts/perf_compare.py --cluster BENCH_cluster.json \
	  --baseline ci/perf-baseline.json

# Install measured perf-smoke figures over the committed PROJECTED
# references: download the `perf-smoke` workflow artifact first, e.g.
#   gh run download --name perf-smoke --dir /tmp/perf-smoke
#   make refresh-baselines ARTIFACT_DIR=/tmp/perf-smoke
refresh-baselines:
	python3 scripts/refresh_baselines.py $(ARTIFACT_DIR) --ratchet

# AOT-compile the PJRT kernel variants (requires the python/JAX toolchain;
# see python/compile/aot.py and DESIGN.md §5).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
