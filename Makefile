# Convenience targets. The rust crate needs none of these — `cargo build`
# is dependency-free; `artifacts` is only for the optional PJRT path.

.PHONY: build test bench artifacts doc fmt

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

fmt:
	cargo fmt --all --check

# AOT-compile the PJRT kernel variants (requires the python/JAX toolchain;
# see python/compile/aot.py and DESIGN.md §5).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
