# Convenience targets. The rust crate needs none of these — `cargo build`
# is dependency-free; `artifacts` is only for the optional PJRT path.

.PHONY: build test bench artifacts doc fmt clippy loadgen

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Measure the service under fire: open-loop Zipf traffic with
# coordinated-omission-corrected latency percentiles while nodes fail and
# recover mid-run (see EXPERIMENTS.md §Service under load).
loadgen:
	cargo run --release -- loadgen --mode open --workload zipf --churn incremental

# AOT-compile the PJRT kernel variants (requires the python/JAX toolchain;
# see python/compile/aot.py and DESIGN.md §5).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
