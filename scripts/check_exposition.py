#!/usr/bin/env python3
"""Validate a `METRICS` text exposition written by `memento loadgen
--expose <path>` (the obs-smoke CI step).

Checks, in the spirit of a strict Prometheus/OpenMetrics scraper:

* every sample line parses as `name{quantile="q"}? value`;
* every sample's metric has a `# TYPE` (summary samples resolve their
  `_sum`/`_count`/quantile series to the base name);
* every `# TYPE` has at least one sample and a matching `# HELP`;
* no metric is TYPE-declared twice;
* the exposition ends with the `# EOF` terminator;
* at least MIN_METRICS metrics are present (an empty-but-well-formed
  file means the registry wiring silently fell off).

Stdlib only; exit 0 on a valid exposition, 1 with a message otherwise.
"""

import re
import sys

# 14: the pre-cache registry exposed well over 10; the hot-key tier
# (memento_cache_hits/misses/coalesced/evictions/invalidations/entries)
# raises the floor so the cache metrics falling off the registry fails
# the obs-smoke scrape instead of passing silently.
MIN_METRICS = 14

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[0-9]+(?:\.[0-9]+)?|[+-]?(?:Inf|NaN))$"
)
KINDS = {"counter", "gauge", "summary"}


def fail(msg):
    print(f"check_exposition: FAIL: {msg}")
    sys.exit(1)


def base_name(sample_name, typed):
    """Resolve a summary's _sum/_count series to its TYPE-declared base."""
    if sample_name in typed:
        return sample_name
    for suffix in ("_sum", "_count"):
        if sample_name.endswith(suffix):
            stem = sample_name[: -len(suffix)]
            if stem in typed:
                return stem
    return sample_name


def main():
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} <exposition.txt>")
    path = sys.argv[1]
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        fail(f"cannot read {path}: {e}")

    if not text.endswith("# EOF\n"):
        fail("exposition must end with the '# EOF' terminator line")

    typed = {}  # name -> kind
    helped = set()
    sampled = set()  # TYPE-resolved base names with >=1 sample
    for lineno, line in enumerate(text.splitlines(), start=1):
        if line == "# EOF":
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not parts[3].strip():
                fail(f"line {lineno}: HELP without text: {line!r}")
            if not NAME_RE.match(parts[2]):
                fail(f"line {lineno}: bad HELP metric name: {line!r}")
            helped.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4:
                fail(f"line {lineno}: malformed TYPE: {line!r}")
            name, kind = parts[2], parts[3]
            if not NAME_RE.match(name):
                fail(f"line {lineno}: bad TYPE metric name: {line!r}")
            if kind not in KINDS:
                fail(f"line {lineno}: unknown kind {kind!r} (want {sorted(KINDS)})")
            if name in typed:
                fail(f"line {lineno}: duplicate TYPE for {name}")
            typed[name] = kind
            continue
        if line.startswith("#"):
            fail(f"line {lineno}: unknown comment directive: {line!r}")
        m = SAMPLE_RE.match(line)
        if not m:
            fail(f"line {lineno}: unparseable sample: {line!r}")
        base = base_name(m.group("name"), typed)
        if base not in typed:
            fail(f"line {lineno}: sample for undeclared metric {m.group('name')!r}")
        labels = m.group("labels")
        if labels and not re.match(r'^quantile="[0-9.]+"$', labels):
            fail(f"line {lineno}: unexpected labels {labels!r}")
        sampled.add(base)

    unsampled = sorted(set(typed) - sampled)
    if unsampled:
        fail(f"TYPE declared but no samples: {unsampled}")
    unhelped = sorted(set(typed) - helped)
    if unhelped:
        fail(f"TYPE without HELP: {unhelped}")
    orphan_help = sorted(helped - set(typed))
    if orphan_help:
        fail(f"HELP without TYPE: {orphan_help}")
    if len(typed) < MIN_METRICS:
        fail(f"only {len(typed)} metrics exposed (expected >= {MIN_METRICS})")

    kinds = {}
    for kind in typed.values():
        kinds[kind] = kinds.get(kind, 0) + 1
    summary = " ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
    print(f"check_exposition: OK: {len(typed)} metrics ({summary})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
