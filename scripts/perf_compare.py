#!/usr/bin/env python3
"""Perf-smoke gate: compare fresh BENCH_*.json figures against the
committed baseline and fail on a >2x throughput regression.

Usage:
    python3 scripts/perf_compare.py \
        --current BENCH_router_scaling.json \
        --loadgen BENCH_loadgen_smoke.json \
        --baseline ci/perf-baseline.json

The cluster-smoke job runs it standalone against the drill payload:

    python3 scripts/perf_compare.py \
        --cluster BENCH_cluster.json --baseline ci/perf-baseline.json

The baseline holds conservative *floors* (see ci/perf-baseline.json):
CI runners are shared and noisy, so the gate only trips when measured
throughput falls below baseline/2 — a real regression (a lock back on
the hot path, an accidental O(n) in the lookup), not runner jitter.
Stdlib only; no third-party packages.
"""

import argparse
import json
import sys

REGRESSION_FACTOR = 2.0


def load(path):
    with open(path) as f:
        return json.load(f)


def cell_throughput(rows, threads):
    for row in rows:
        if row.get("threads") == threads:
            return float(row["throughput"])
    raise SystemExit(f"no row for {threads} threads in {rows!r}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", help="BENCH_router_scaling.json from this run (optional)")
    ap.add_argument("--loadgen", help="BENCH_loadgen_smoke.json from this run (optional)")
    ap.add_argument("--migration", help="BENCH_migration.json from this run (optional)")
    ap.add_argument("--weighted", help="BENCH_weighted.json from this run (optional)")
    ap.add_argument("--wal", help="BENCH_wal.json from this run (optional)")
    ap.add_argument("--obs", help="BENCH_obs.json from this run (optional)")
    ap.add_argument("--conn", help="BENCH_conn.json from this run (optional)")
    ap.add_argument("--hotset", help="BENCH_hotset.json from this run (optional)")
    ap.add_argument("--cluster", help="BENCH_cluster.json from this run (optional)")
    ap.add_argument("--baseline", required=True, help="committed ci/perf-baseline.json")
    args = ap.parse_args()

    current = load(args.current) if args.current else None
    baseline = load(args.baseline)
    failures = []
    checks = []

    def gate(name, measured, floor):
        threshold = floor / REGRESSION_FACTOR
        ok = measured >= threshold
        checks.append((name, measured, floor, threshold, ok))
        if not ok:
            failures.append(name)

    def gate_ceiling(name, measured, ceiling):
        # Absolute ceiling (no noise factor): correctness-shaped figures
        # like balance error don't jitter the way throughput does.
        ok = measured <= ceiling
        checks.append((name, measured, ceiling, ceiling, ok))
        if not ok:
            failures.append(name)

    if current is not None:
        for threads, floor in baseline["loadgen_closed_ops_s"].items():
            measured = cell_throughput(current["loadgen_closed"], int(threads))
            gate(f"loadgen closed @ {threads} threads", measured, floor)
        for threads, floor in baseline["route_only_ops_s"].items():
            measured = cell_throughput(current["route_only"], int(threads))
            gate(f"route-only @ {threads} threads", measured, floor)

    if args.loadgen:
        smoke = load(args.loadgen)
        gate(
            "loadgen smoke (8-thread closed loop)",
            float(smoke["throughput"]),
            baseline["loadgen_smoke_ops_s"],
        )
        if int(smoke.get("errors", 0)) != 0:
            failures.append("loadgen smoke reported errors")
            checks.append(("loadgen smoke errors", smoke["errors"], 0, 0, False))

    if args.migration:
        mig = load(args.migration)
        # Admin ops/s is the O(1)-admin-path pin: key scanning creeping
        # back into KILL/ADD shows up as a cliff here, not jitter.
        gate(
            "migration admin ops/s (worst cell)",
            float(mig["admin_ops_s_min"]),
            baseline["migration_admin_ops_s"],
        )
        gate(
            "migration drain keys/s (worst cell)",
            float(mig["drain_keys_per_s_min"]),
            baseline["migration_drain_keys_per_s"],
        )

    if args.weighted:
        wtd = load(args.weighted)
        # Weighting is node-layer only: the lookup hot path must not
        # slow down as skew grows.
        gate(
            "weighted lookup ops/s (worst cell)",
            float(wtd["lookup_ops_s_min"]),
            baseline["weighted_lookup_ops_s"],
        )
        # Balance error vs configured weights is a ceiling, not a floor.
        gate_ceiling(
            "weighted balance err (worst cell, ceiling)",
            float(wtd["balance_err_max"]),
            baseline["weighted_balance_err_max"],
        )

    if args.wal:
        wal = load(args.wal)
        # Group commit (one fsync amortized over 64 appends) and the
        # page-cache bound. The `always` cell is deliberately not gated:
        # it measures the shared runner's raw fsync latency, which
        # varies by >10x across runner disks.
        gate(
            "wal batch64 puts/s (group commit)",
            float(wal["wal_batch_puts_per_s"]),
            baseline["wal_batch_puts_per_s"],
        )
        gate(
            "wal osonly puts/s (page-cache bound)",
            float(wal["wal_osonly_puts_per_s"]),
            baseline["wal_osonly_puts_per_s"],
        )

    if args.obs:
        obs = load(args.obs)
        # The spanned route path must stay fast in absolute terms...
        gate(
            "obs route-span ops/s",
            float(obs["obs_route_span_ops_s"]),
            baseline["obs_route_span_ops_s"],
        )
        # ...and the relative tax of instrumentation on the wait-free
        # read path is a hard ceiling: both cells run interleaved on the
        # same runner, so the ratio is noise-resistant in a way absolute
        # throughput is not.
        gate_ceiling(
            "obs route-span overhead pct (ceiling)",
            float(obs["obs_route_overhead_pct"]),
            baseline["obs_route_overhead_pct_max"],
        )

    if args.conn:
        conn = load(args.conn)
        # The binary codec strips line rendering/parsing from the hot
        # path; a single connection must clear the same kind of floor
        # the text protocol does.
        gate(
            "conn binary lookup ops/s (1 conn)",
            float(conn["conn_bin_lookup_ops_s"]),
            baseline["conn_bin_lookup_ops_s"],
        )
        # The event-loop contract: 1k+ open connections served open-loop
        # at the target rate by a bounded worker pool.
        gate(
            "conn 1k-connection open-loop ops/s",
            float(conn["conn_1k_ops_s"]),
            baseline["conn_1k_ops_s"],
        )
        # Tail ceiling in absolute microseconds: a stalled worker pool
        # or a lost-wakeup bug shows up as a p99.9 cliff, not jitter.
        gate_ceiling(
            "conn 1k-connection p99.9 us (ceiling)",
            float(conn["conn_p999_us"]),
            baseline["conn_p999_us_max"],
        )
        ratio = conn.get("bin_vs_text")
        if ratio is not None:
            print(f"binary vs text single-conn LOOKUP: {ratio}x (informational)")

    if args.hotset:
        hot = load(args.hotset)
        # The cached GET path under Zipf s=1.2 skew is the tier's
        # raison d'etre; a cache that stops serving hits regresses this
        # cell to the uncached floor, a far bigger cliff than jitter.
        gate(
            "hotset cached GET ops/s (zipf s=1.2)",
            float(hot["hotset_get_ops_s"]),
            baseline["hotset_get_ops_s"],
        )
        # Hit rate is correctness-shaped (how much of the analytic head
        # mass the CLOCK tier retains), so it gets an absolute floor —
        # no noise factor.
        hit = float(hot["hotset_hit_rate"])
        floor = baseline["hotset_hit_rate_min"]
        ok = hit >= floor
        checks.append(("hotset hit rate (floor, absolute)", hit, floor, floor, ok))
        if not ok:
            failures.append("hotset hit rate (floor, absolute)")
        # Epoch validity + write-through invalidation: a single stale
        # read under churn is a consistency bug, never jitter.
        gate_ceiling(
            "hotset stale reads under churn (ceiling)",
            float(hot["hotset_stale_reads"]),
            0,
        )
        speed = hot.get("hotset_speedup_1_2")
        if speed is not None:
            print(f"hot-key cache speedup at zipf s=1.2: {speed}x (informational)")

    if args.cluster:
        clu = load(args.cluster)
        n_faults = int(clu["faults"])
        # Every scheduled fault must be confirmed by the detector (which
        # is what drives the KILLN + drain) and every downed node must
        # rejoin — these are exact counts, not noisy figures.
        for figure, label in (("detections", "cluster detections"), ("rejoins", "cluster rejoins")):
            got = int(clu[figure])
            ok = got == n_faults
            checks.append((f"{label} (== faults)", got, n_faults, n_faults, ok))
            if not ok:
                failures.append(f"{label} (== faults)")
        # Zero acked-write loss is the drill's core invariant: a single
        # lost write is a durability bug, never runner jitter.
        gate_ceiling("cluster lost writes (ceiling)", float(clu["lost_writes"]), 0)
        # Detection latency rides the probe cadence, not CPU speed, so a
        # generous absolute ceiling catches a stuck detector without
        # flaking on slow runners.
        gate_ceiling(
            "cluster detect ms max (ceiling)",
            float(clu["detect_ms_max"]),
            baseline["cluster_detect_ms_max"],
        )
        # Availability floor is absolute: the write path must keep
        # serving through single-node faults at replicas=2.
        avail = float(clu["availability_min"])
        floor = baseline["cluster_availability_min"]
        ok = avail >= floor
        checks.append(("cluster availability min (floor, absolute)", avail, floor, floor, ok))
        if not ok:
            failures.append("cluster availability min (floor, absolute)")
        if not bool(clu.get("pass", False)):
            failures.append("cluster drill self-verdict")
            checks.append(("cluster drill self-verdict", 0, 1, 1, False))

    width = max(len(c[0]) for c in checks)

    def fmt(v):
        # Sub-unit figures (balance error) need decimals; throughputs don't.
        return f"{v:>12.4f}" if abs(v) < 10 else f"{v:>12.0f}"

    for name, measured, floor, threshold, ok in checks:
        verdict = "ok" if ok else "REGRESSION"
        print(
            f"{name:<{width}}  measured {fmt(measured)}  "
            f"baseline {fmt(floor)}  gate {fmt(threshold)}  {verdict}"
        )

    scaling = current.get("loadgen_speedup_8v1") if current is not None else None
    if scaling is not None:
        cores = current.get("cores", "?")
        print(f"\nloadgen speedup 8v1: {scaling}x on {cores} cores (informational)")

    if failures:
        print(f"\nFAIL: {len(failures)} perf regression(s): {', '.join(failures)}")
        return 1
    print(f"\nOK: {len(checks)} checks within {REGRESSION_FACTOR}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
