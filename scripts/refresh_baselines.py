#!/usr/bin/env python3
"""Install measured perf-smoke figures over the committed references.

The committed BENCH_*.json files at the repo root started life as
PROJECTED references (the authoring environment had no Rust toolchain —
see CHANGES.md PR 3/PR 6). Every CI perf-smoke run uploads the real
measured JSONs as the `perf-smoke` workflow artifact. This script takes
a downloaded artifact directory and replaces the committed references
with those measured runs, refusing anything that still carries a
PROJECTED note or is missing its gate figures:

    gh run download --name perf-smoke --dir /tmp/perf-smoke
    python3 scripts/refresh_baselines.py /tmp/perf-smoke
    git diff BENCH_*.json   # review, then commit

With --ratchet it also prints suggested ci/perf-baseline.json floors
(2/3 of each measured gate figure: tighter than the deliberately loose
pre-measurement floors, still slack enough for shared-runner jitter).
Stdlib only; no third-party packages.
"""

import argparse
import json
import os
import sys

# Committed reference -> the keys a measured run must carry (the gate
# figures perf_compare.py reads, plus the rows they are derived from).
REFERENCES = {
    "BENCH_router_scaling.json": ["loadgen_closed", "route_only"],
    "BENCH_migration.json": ["admin_ops_s_min", "drain_keys_per_s_min"],
    "BENCH_weighted.json": ["lookup_ops_s_min", "balance_err_max"],
    "BENCH_wal.json": ["wal_batch_puts_per_s", "wal_osonly_puts_per_s"],
    "BENCH_conn.json": ["conn_bin_lookup_ops_s", "conn_1k_ops_s", "conn_p999_us"],
    "BENCH_hotset.json": ["hotset_get_ops_s", "hotset_hit_rate", "hotset_stale_reads"],
    "BENCH_cluster.json": [
        "detections",
        "rejoins",
        "detect_ms_max",
        "lost_writes",
        "availability_min",
    ],
}

# (baseline key, source file, gate figure key) for --ratchet.
RATCHETS = [
    ("migration_admin_ops_s", "BENCH_migration.json", "admin_ops_s_min"),
    ("migration_drain_keys_per_s", "BENCH_migration.json", "drain_keys_per_s_min"),
    ("weighted_lookup_ops_s", "BENCH_weighted.json", "lookup_ops_s_min"),
    ("wal_batch_puts_per_s", "BENCH_wal.json", "wal_batch_puts_per_s"),
    ("wal_osonly_puts_per_s", "BENCH_wal.json", "wal_osonly_puts_per_s"),
    ("conn_bin_lookup_ops_s", "BENCH_conn.json", "conn_bin_lookup_ops_s"),
    ("conn_1k_ops_s", "BENCH_conn.json", "conn_1k_ops_s"),
    ("hotset_get_ops_s", "BENCH_hotset.json", "hotset_get_ops_s"),
]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact_dir", help="downloaded perf-smoke artifact directory")
    ap.add_argument("--repo-root", default=os.path.join(os.path.dirname(__file__), ".."))
    ap.add_argument(
        "--ratchet",
        action="store_true",
        help="also print suggested ci/perf-baseline.json floors (2/3 of measured)",
    )
    args = ap.parse_args()

    installed, skipped = [], []
    for name, required in REFERENCES.items():
        src = os.path.join(args.artifact_dir, name)
        if not os.path.exists(src):
            skipped.append((name, "not in artifact"))
            continue
        with open(src) as f:
            data = json.load(f)
        if "PROJECTED" in str(data.get("note", "")):
            skipped.append((name, "still carries a PROJECTED note — not a measured run"))
            continue
        missing = [k for k in required if k not in data]
        if missing:
            skipped.append((name, f"missing gate figures {missing}"))
            continue
        dst = os.path.join(args.repo_root, name)
        with open(dst, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        installed.append(name)
        print(f"installed {name} (measured run -> {dst})")

    for name, why in skipped:
        print(f"skipped {name}: {why}")

    if args.ratchet and installed:
        print("\nsuggested ci/perf-baseline.json floors (2/3 of measured):")
        for key, src_name, figure in RATCHETS:
            if src_name not in installed:
                continue
            with open(os.path.join(args.repo_root, src_name)) as f:
                measured = float(json.load(f)[figure])
            print(f'  "{key}": {int(measured * 2 / 3)},')

    if not installed:
        print("nothing installed — is this a perf-smoke artifact directory?")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
