//! Failure drill: the §I elasticity story, quantified.
//!
//! ```bash
//! cargo run --release --example failure_drill
//! ```
//!
//! Walks a 100-node Memento cluster through escalating failure waves
//! (5% → 50%), measuring after each wave what the paper's propositions
//! promise: relocated share ≈ failed share (minimal disruption), balance
//! χ² stays uniform (Prop. VI.4), lookup cost grows like ln²(n/w)
//! (Prop. VII.3), and memory stays Θ(r) (12-16 bytes per failure).

use memento::algorithms::{ConsistentHasher, Memento, RemovalOrder};
use memento::benchkit::report::Table;
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::simulator::{audit, scenario};

fn main() {
    let w0 = 100usize;
    let mut m = Memento::new(w0);
    let keys: Vec<u64> =
        (0..300_000u64).map(memento::hashing::mix::splitmix64_mix).collect();
    let mut rng = Xoshiro256::new(0xD1A);

    let mut t = Table::new(
        "failure drill — 100-node memento cluster",
        &[
            "wave", "failed_total", "working", "relocated%", "expected%",
            "collateral", "balance_maxdev%", "mean_iters", "ln2(n/w)", "state_bytes",
        ],
    );

    let mut before: Vec<u32> = keys.iter().map(|k| m.lookup(*k)).collect();
    let mut failed_total = 0usize;
    for (wave, frac) in [0.05f64, 0.10, 0.20, 0.35, 0.50].iter().enumerate() {
        let target = (w0 as f64 * frac) as usize;
        let step = target - failed_total;
        let removed = scenario::apply_removals(&mut m, step, RemovalOrder::Random, &mut rng);
        failed_total = target;

        let after: Vec<u32> = keys.iter().map(|k| m.lookup(*k)).collect();
        let rep = audit::disruption(&before, &after, &keys, &removed);
        let bal = audit::balance(&m, &keys);
        let mut iters = 0u64;
        let probes = 20_000;
        for _ in 0..probes {
            let tr = m.lookup_traced(rng.next_u64());
            iters += (tr.outer_iters.max(1) * tr.inner_iters.max(1)) as u64;
        }
        let nf = m.size() as f64;
        let wf = m.working() as f64;
        t.push_row(vec![
            (wave + 1).to_string(),
            failed_total.to_string(),
            m.working().to_string(),
            format!("{:.2}", rep.relocated as f64 / keys.len() as f64 * 100.0),
            format!("{:.2}", step as f64 / (wf + step as f64) * 100.0),
            rep.collateral.to_string(),
            format!("{:.2}", bal.max_deviation * 100.0),
            format!("{:.2}", iters as f64 / probes as f64),
            format!("{:.2}", (1.0 + (nf / wf).ln()).powi(2)),
            m.state_bytes().to_string(),
        ]);
        assert_eq!(rep.collateral, 0, "minimal disruption violated");
        before = after;
    }
    t.emit("failure_drill");
    println!("all waves: 0 collateral moves — Prop. VI.3 holds under escalating failures");
}
