//! Quickstart: the 5-minute tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers: building a MementoHash cluster, looking up keys, surviving a
//! random node failure (the thing JumpHash cannot do), restoring it, and
//! reading the paper's §III properties off the auditors.

use memento::algorithms::{ConsistentHasher, Memento};
use memento::hashing::xxhash::xxhash64;
use memento::simulator::audit;

fn main() {
    // A fresh cluster of 10 nodes: buckets 0..9, no internal state at all
    // beyond the integer 10 (Alg. 1).
    let mut cluster = Memento::new(10);
    println!("cluster: {} working buckets, state = {} bytes (empty R)",
        cluster.working(), cluster.state_bytes());

    // Keys are anything hashable — digest once at the edge, then route.
    for name in ["alice.jpg", "bob.mp4", "carol.db"] {
        let key = xxhash64(name.as_bytes(), 0);
        println!("  {name:<10} -> bucket {}", cluster.lookup(key));
    }

    // Node 5's machine catches fire. Jump can't express this; Memento
    // records one replacement tuple ⟨5 → 8, 10⟩ (Alg. 2) and carries on.
    cluster.remove(5).expect("bucket 5 was working");
    println!("\nafter failing bucket 5: w={}, |R|={}, state = {} bytes",
        cluster.working(), cluster.removed(), cluster.state_bytes());
    for name in ["alice.jpg", "bob.mp4", "carol.db"] {
        let key = xxhash64(name.as_bytes(), 0);
        let b = cluster.lookup(key);
        assert_ne!(b, 5, "failed bucket must never be returned");
        println!("  {name:<10} -> bucket {b}");
    }

    // Minimal disruption, measured not assumed: only keys that lived on
    // bucket 5 moved (Prop. VI.3).
    let keys: Vec<u64> = (0..200_000u64)
        .map(|i| memento::hashing::mix::splitmix64_mix(i))
        .collect();
    let balance = audit::balance(&cluster, &keys);
    println!("\nbalance over {} keys x {} buckets: max deviation {:.2}%, peak/avg {:.3}",
        balance.keys, balance.buckets, balance.max_deviation * 100.0, balance.peak_to_avg);
    assert!(balance.is_uniform(6.0));

    // The machine comes back: add() restores the SAME bucket (Alg. 3),
    // and only the keys that left it move back (Prop. VI.5).
    let before: Vec<u32> = keys.iter().map(|k| cluster.lookup(*k)).collect();
    let restored = cluster.add().unwrap();
    let mut came_back = 0;
    for (k, old) in keys.iter().zip(&before) {
        let new = cluster.lookup(*k);
        if new != *old {
            assert_eq!(new, restored);
            came_back += 1;
        }
    }
    println!("\nrestored bucket {restored}: {came_back} keys moved back (≈ {} expected), 0 collateral",
        keys.len() / 10);

    // Scale out past the original size: buckets are handed out densely.
    let b10 = cluster.add().unwrap();
    let b11 = cluster.add().unwrap();
    println!("scaled out: new buckets {b10}, {b11}; w={}", cluster.working());
    println!("\nquickstart OK");
}
