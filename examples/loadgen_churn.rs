//! Loadgen demo: open-loop Zipf traffic through real TCP while nodes fail
//! and recover mid-run.
//!
//! ```bash
//! cargo run --release --example loadgen_churn
//! ```
//!
//! Boots the replicated KV service on a loopback port, preloads the hot
//! keyspace, then runs the paper's *incremental* scenario end-to-end: a
//! paced (coordinated-omission-corrected) open-loop workload measures
//! p50/p99/p999 latency while the churn injector kills four nodes through
//! the run and restores them near the end — the degradation-under-failures
//! measurement AnchorHash and DxHash report, taken through the whole
//! serving stack instead of the algorithm alone.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::loadgen::{self, ChurnScenario, LoadgenConfig, Mode, Target as _, Workload};
use std::time::Duration;

fn main() {
    let nodes = 16;
    let router = Router::new("memento", nodes, nodes * 10, None).expect("router");
    let service = Service::with_replicas(router.clone(), 2);
    let server = service.serve("127.0.0.1:0", 64).expect("bind");
    println!("loadgen_churn: {nodes} nodes, replicas=2, serving on {}", server.addr());

    let factory = loadgen::target::tcp_factory(server.addr());
    let loaded = loadgen::preload(&factory, 20_000).expect("preload");
    println!("preloaded {loaded} records");

    let cfg = LoadgenConfig {
        mode: Mode::Open { rate: 20_000.0 },
        workload: Workload::zipf(100_000, 1.1, 0.7),
        threads: 4,
        duration: Duration::from_secs(3),
        churn: ChurnScenario::Incremental { kills: 4 },
        cluster_buckets: nodes as u32,
        seed: 7,
    };
    let report = loadgen::run(&cfg, &factory).expect("run");
    println!("{}", report.render());

    let mut admin = factory().expect("admin connection");
    println!("{}", admin.call("STATS").expect("stats"));
    drop(admin);

    assert!(report.ops > 0, "no traffic was measured");
    assert_eq!(
        router.epoch(),
        8,
        "4 kills + 4 restores must have fired through the protocol"
    );
    assert_eq!(router.working(), nodes, "all capacity restored");
    assert_eq!(server.shutdown(), 0, "all connections drained");
    println!("loadgen_churn OK");
}
