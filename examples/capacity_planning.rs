//! Capacity planning: what fixing `a` up front actually costs (§VIII-E).
//!
//! ```bash
//! cargo run --release --example capacity_planning
//! ```
//!
//! An operator sizing a cluster with Anchor or Dx must guess the maximum
//! size it will ever reach. This example quantifies the penalty of each
//! guess (a/w ∈ {5..100}) in memory and lookup latency against Memento,
//! which needs no guess at all — then shows the failure mode the guess
//! creates: the cluster simply cannot grow past it.

use memento::algorithms::{AlgoError, ConsistentHasher};
use memento::benchkit::report::Table;
use memento::simulator::scenario::{self, ScenarioConfig};

fn main() {
    let w = 10_000usize;
    let cfg = ScenarioConfig { keys: 50_000, ..Default::default() };

    let mut t = Table::new(
        "capacity planning — the cost of guessing a (w = 10k, 20% failed)",
        &["algo", "a/w", "state", "lookup_ns", "vs_memento_mem", "vs_memento_ns"],
    );
    let base = scenario::sensitivity_cell("memento", w, 1, 0.2, &cfg);
    t.push_row(vec![
        "memento".into(),
        "(unbounded)".into(),
        memento::benchkit::fmt_bytes(base.state_bytes),
        format!("{:.0}", base.lookup.median_ns),
        "1.0x".into(),
        "1.0x".into(),
    ]);
    for algo in ["anchor", "dx"] {
        for ratio in [5usize, 10, 20, 50, 100] {
            let c = scenario::sensitivity_cell(algo, w, ratio, 0.2, &cfg);
            t.push_row(vec![
                algo.into(),
                ratio.to_string(),
                memento::benchkit::fmt_bytes(c.state_bytes),
                format!("{:.0}", c.lookup.median_ns),
                format!("{:.0}x", c.state_bytes as f64 / base.state_bytes.max(1) as f64),
                format!("{:.1}x", c.lookup.median_ns / base.lookup.median_ns),
            ]);
        }
    }
    t.emit("capacity_planning");

    // The hard wall: a capacity-bound cluster cannot scale past a.
    let mut anchor = memento::algorithms::anchor::Anchor::new(w * 2, w);
    let mut grown = 0;
    loop {
        match anchor.add() {
            Ok(_) => grown += 1,
            Err(AlgoError::CapacityExhausted { capacity }) => {
                println!(
                    "anchor with a=2w hit its wall after {grown} additions (capacity {capacity}); \
                     memento has no such wall:"
                );
                break;
            }
            Err(e) => panic!("{e}"),
        }
    }
    let mut m = memento::algorithms::Memento::new(w);
    for _ in 0..w * 3 {
        m.add().unwrap();
    }
    println!("  memento grew from {w} to {} nodes without reconfiguration", m.working());
}
