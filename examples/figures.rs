//! Regenerate EVERY paper figure in one run (CSV under `results/`).
//!
//! ```bash
//! cargo run --release --example figures              # CI scale
//! MEMENTO_BENCH_SCALE=full cargo run --release --example figures  # paper scale
//! ```
//!
//! Equivalent to `memento figures` / `cargo bench`, packaged as the
//! example a reader reaches for first. See DESIGN.md §4 for the
//! figure ↔ module ↔ bench index and EXPERIMENTS.md for recorded runs.

use memento::simulator::{figures, Scale, ScenarioConfig};

fn main() {
    let scale = Scale::from_env();
    let mut cfg = ScenarioConfig::default();
    cfg.keys = scale.keys_per_cell().min(200_000);
    println!("scale: {scale:?} (set MEMENTO_BENCH_SCALE=full for paper sizes)\n");

    let t = figures::fig_17_18_stable(scale, &cfg);
    t.emit("fig_17_18_stable");
    for finding in figures::check_stable_shape(&t) {
        println!("note: {finding}");
    }
    figures::fig_19_22_oneshot(scale, &cfg).emit("fig_19_22_oneshot");
    figures::fig_23_26_incremental(scale, &cfg).emit("fig_23_26_incremental");
    figures::fig_27_32_sensitivity(scale, &cfg).emit("fig_27_32_sensitivity");
    println!("all figure CSVs written to results/");
}
