//! §X future work, implemented: *"maintain full consistency when nodes
//! may not unanimously agree on the removal order"*.
//!
//! ```bash
//! cargo run --release --example replica_consistency
//! ```
//!
//! Memento's replacement tuples capture the working count *at removal
//! time*, so replicas that apply the same failures in different orders
//! route keys differently. This example sweeps the network-reordering
//! window and compares the naive eager policy against sequence-fenced
//! application (the leader stamps a total order; replicas buffer gaps):
//! eager divergence grows with the window, fenced stays at exactly zero.

use memento::benchkit::report::Table;
use memento::coordinator::replica::reorder_experiment;

fn main() {
    let mut t = Table::new(
        "removal-order consistency — 3 replicas, 64-node cluster, 80 events",
        &[
            "reorder_window",
            "eager_divergence%",
            "eager_dropped_events",
            "fenced_divergence%",
            "fenced_buffer_peak",
        ],
    );
    for window in [0usize, 2, 4, 8, 16, 32] {
        // Average a few seeds per window.
        let (mut ed, mut dr, mut fd, mut bp) = (0.0, 0u64, 0.0, 0usize);
        let seeds = 5;
        for seed in 0..seeds {
            let r = reorder_experiment(64, 80, 3, window, seed);
            ed += r.eager_divergence;
            dr += r.eager_dropped;
            fd += r.fenced_divergence;
            bp = bp.max(r.fenced_buffer_peak);
        }
        t.push_row(vec![
            window.to_string(),
            format!("{:.2}", ed / seeds as f64 * 100.0),
            dr.to_string(),
            format!("{:.2}", fd / seeds as f64 * 100.0),
            bp.to_string(),
        ]);
    }
    t.emit("replica_consistency");
    println!(
        "fenced application (the leader's sequence numbers) keeps every replica\n\
         bit-identical to the leader at any reorder window — the practical answer\n\
         to the paper's §X open question."
    );
}
