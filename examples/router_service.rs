//! Router service demo: the TCP front-end under live traffic + chaos.
//!
//! ```bash
//! cargo run --release --example router_service
//! ```
//!
//! Boots the full service on a loopback port, runs concurrent client
//! threads doing PUT/GET traffic, kills and restores nodes mid-flight via
//! the admin protocol, and prints the service metrics — the deployment
//! smoke test for the coordinator stack.

use memento::coordinator::router::Router;
use memento::coordinator::service::Service;
use memento::netserver::{Client, ClientError};
use memento::proto::Request;
use std::time::{Duration, Instant};

/// One text-protocol request through the typed client API
/// (`Client::call`); the response — or typed error — is rendered back
/// to its wire line so output stays line-oriented. Replaces the
/// deprecated raw-line `Client::request` shim (DESIGN.md §13).
fn req(c: &mut Client, line: &str) -> String {
    let parsed = match Request::parse_text(line) {
        Ok(r) => r,
        Err(e) => return e.render_text(),
    };
    match c.call(&parsed) {
        Ok(resp) => resp.render_text(),
        Err(ClientError::Proto(e)) => e.render_text(),
        Err(ClientError::Io(e)) => panic!("transport failure on {line:?}: {e}"),
    }
}

fn main() {
    let router = Router::new("memento", 16, 160, None).expect("router");
    let service = Service::new(router);
    let server = service.serve("127.0.0.1:0", 128).expect("bind");
    let addr = server.addr();
    println!("router service on {addr} (16 nodes, memento)");

    let t0 = Instant::now();
    let writers: Vec<_> = (0..6)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let mut ok = 0u32;
                for i in 0..2_000 {
                    let r = req(&mut c, &format!("PUT tenant{t}:obj{i} payload-{t}-{i}"));
                    assert!(r.starts_with("OK"), "{r}");
                    ok += 1;
                }
                ok
            })
        })
        .collect();

    // Chaos alongside the writers.
    let chaos = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        for bucket in [3u32, 11, 7] {
            let r = req(&mut c, &format!("KILL {bucket}"));
            println!("  chaos: {r}");
            std::thread::sleep(Duration::from_millis(15));
        }
        for _ in 0..3 {
            let r = req(&mut c, "ADD");
            println!("  chaos: {r}");
            std::thread::sleep(Duration::from_millis(10));
        }
    });

    let total: u32 = writers.into_iter().map(|w| w.join().unwrap()).sum();
    chaos.join().unwrap();
    let dt = t0.elapsed();
    println!(
        "wrote {total} records through {} in {:.2?} ({:.0} req/s incl. chaos)",
        addr,
        dt,
        total as f64 / dt.as_secs_f64()
    );

    // Verify all data survived the chaos.
    let mut c = Client::connect(&addr).unwrap();
    let mut verified = 0u32;
    for t in 0..6 {
        for i in (0..2_000).step_by(7) {
            let r = req(&mut c, &format!("GET tenant{t}:obj{i}"));
            assert!(r.contains(&format!("payload-{t}-{i}")), "lost tenant{t}:obj{i}: {r}");
            verified += 1;
        }
    }
    println!("verified {verified} sampled records post-chaos — zero loss");
    println!("{}", req(&mut c, "STATS"));
    println!("{}", req(&mut c, "EPOCH"));
    server.shutdown();
    println!("router_service OK");
}
