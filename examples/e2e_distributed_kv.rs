//! END-TO-END DRIVER: the full system on a realistic workload.
//!
//! ```bash
//! cargo run --release --example e2e_distributed_kv
//! ```
//!
//! Exercises every layer together (recorded in EXPERIMENTS.md §E2E):
//!  L3 rust coordinator — router + membership + dynamic batcher + storage;
//!  runtime            — the batched lookup engine (pure-Rust lockstep
//!                       backend by default; PJRT with `--features pjrt`
//!                       and `make artifacts`);
//!  substrate          — in-process KV nodes with real data migration.
//!
//! Phases:
//!  1. load 200k records through the router (zipf-skewed key popularity);
//!  2. serve 1M batched lookups, report throughput + latency percentiles;
//!  3. kill 20% of the nodes one by one, migrating data each time, with
//!     the rebalance auditor checking the minimal-disruption bound live;
//!  4. serve reads again — every record must be found, zero loss;
//!  5. restore the nodes; audit monotonicity; report final stats.

use memento::coordinator::batcher::Batcher;
use memento::coordinator::rebalancer::Rebalancer;
use memento::coordinator::router::Router;
use memento::coordinator::storage::StorageCluster;
use memento::hashing::keygen::{KeyDistribution, KeyStream};
use memento::hashing::prng::{Rng64, Xoshiro256};
use memento::metrics::Histogram;
use memento::runtime::EngineHandle;
use std::sync::Arc;
use std::time::{Duration, Instant};

const NODES: usize = 50;
const RECORDS: usize = 200_000;
const LOOKUPS: usize = 1_000_000;
const KILL_FRAC: f64 = 0.2;

fn main() {
    let t_start = Instant::now();

    // --- build the stack -------------------------------------------------
    let engine = match EngineHandle::spawn("artifacts".into()) {
        Ok(h) if h.info().has_memento => {
            println!("[engine] batched lookups on {}", h.info().platform);
            Some(h)
        }
        Ok(_) => {
            println!("[engine] backend has no memento kernel — scalar lookups");
            None
        }
        Err(e) => {
            println!("[engine] unavailable ({e}) — scalar lookups");
            None
        }
    };
    let engine_for_stats = engine.clone();
    let router = Router::new("memento", NODES, NODES * 10, engine).expect("router");
    let storage = Arc::new(StorageCluster::new());
    let rebalancer = Rebalancer::new(&router, 100_000, 0xE2E);

    // --- phase 1: load ----------------------------------------------------
    let mut ks = KeyStream::new(
        KeyDistribution::Zipf { universe: RECORDS as u64 * 4, alpha: 1.1 },
        7,
    );
    let t = Instant::now();
    let mut record_keys = Vec::with_capacity(RECORDS);
    for _ in 0..RECORDS {
        let k = ks.next_key();
        let (_b, node) = router.route(k);
        storage.node(node).put(k, k.to_le_bytes().to_vec());
        record_keys.push(k);
    }
    record_keys.sort_unstable();
    record_keys.dedup();
    println!(
        "phase 1: loaded {RECORDS} writes ({} distinct keys) across {NODES} nodes in {:?}",
        record_keys.len(),
        t.elapsed()
    );
    let loads = storage.load_by_node();
    let max = loads.iter().map(|(_, c)| *c).max().unwrap();
    let min = loads.iter().map(|(_, c)| *c).min().unwrap();
    println!("         per-node records: min {min}, max {max} (peak/avg {:.2})",
        max as f64 * NODES as f64 / storage.total_records() as f64);

    // --- phase 2: batched lookup serving ----------------------------------
    let (batcher, handle) = Batcher::spawn(router.clone(), 4096, Duration::from_micros(150));
    let mut lat = Histogram::new();
    let t = Instant::now();
    let mut served = 0usize;
    let mut stream = KeyStream::new(KeyDistribution::Uniform, 99);
    while served < LOOKUPS {
        // Pipelined client: submit a burst, then collect (models a
        // front-end fanning requests into the batcher).
        let burst = 8192.min(LOOKUPS - served);
        let t0 = Instant::now();
        let rxs: Vec<_> =
            (0..burst).map(|_| handle.lookup_async(stream.next_key()).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        lat.record(t0.elapsed().as_nanos() as u64 / burst as u64);
        served += burst;
    }
    let dt = t.elapsed();
    println!(
        "phase 2: served {LOOKUPS} lookups in {:.2?} — {:.1}k lookups/s, per-key ns p50={} p99={}",
        dt,
        LOOKUPS as f64 / dt.as_secs_f64() / 1e3,
        lat.quantile(0.5),
        lat.quantile(0.99),
    );
    println!("         router: {}", router.metrics.summary());

    // --- phase 3: failure storm -------------------------------------------
    let kills = (NODES as f64 * KILL_FRAC) as usize;
    let mut rng = Xoshiro256::new(13);
    let t = Instant::now();
    let mut migrated_total = 0usize;
    for i in 0..kills {
        let wb = router.with_view(|a, _| a.working_buckets());
        let victim = wb[rng.next_index(wb.len())];
        let node = router.fail_bucket(victim).expect("fail");
        let r2 = router.clone();
        let moved = storage.migrate_from(node, move |k| r2.route(k).1);
        migrated_total += moved;
        let s = rebalancer.observe_epoch(&router, &[victim]);
        assert_eq!(s.violations, 0, "minimal-disruption violated at kill {i}");
    }
    println!(
        "phase 3: killed {kills} nodes in {:?}; migrated {migrated_total} records; \
         rebalance audit: 0 violations over {} epochs",
        t.elapsed(),
        kills
    );

    // --- phase 4: verify every record survives -----------------------------
    let t = Instant::now();
    for &k in &record_keys {
        let (_b, node) = router.route(k);
        assert!(
            storage.node(node).get(k).is_some(),
            "record {k:#x} lost after failures"
        );
    }
    println!(
        "phase 4: all {} records located post-failure in {:?} (zero loss)",
        record_keys.len(),
        t.elapsed()
    );

    // --- phase 5: restore + monotonicity audit -----------------------------
    for _ in 0..kills {
        let (b, node) = router.add_node().expect("restore");
        // Pull back keys that belong to the restored node (monotone move).
        let r2 = router.clone();
        let mut pulled = 0usize;
        for (id, _) in storage.load_by_node() {
            if id == node {
                continue;
            }
            let src = storage.node(id);
            for k in src.keys() {
                if r2.route(k).1 == node {
                    if let Some(v) = src.delete(k) {
                        storage.node(node).put(k, v);
                        pulled += 1;
                    }
                }
            }
        }
        let s = rebalancer.observe_epoch(&router, &[b]);
        assert_eq!(s.violations, 0, "monotonicity violated restoring {b}");
        let _ = pulled;
    }
    let s = rebalancer.summary();
    println!(
        "phase 5: restored {kills} nodes; audit total: epochs={} relocated={} violations={}",
        s.epochs_observed, s.relocated, s.violations
    );
    for &k in record_keys.iter().step_by(37) {
        let (_b, node) = router.route(k);
        assert!(storage.node(node).get(k).is_some());
    }

    if let Some(h) = engine_for_stats {
        let (device, fallback, dispatches) = h.stats();
        println!(
            "engine: {device} keys on-device over {dispatches} dispatches, {fallback} scalar fallbacks ({:.4}%)",
            fallback as f64 / (device + fallback).max(1) as f64 * 100.0
        );
    }
    drop(handle);
    batcher.join();
    println!("\nE2E OK in {:?}", t_start.elapsed());
}
